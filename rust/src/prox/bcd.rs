//! CA-Prox-BCD — proximal primal block coordinate descent with the s-step
//! communication-avoiding unrolling.
//!
//! SPMD layout, sampling, Gram engine and the **one packed `[G|r]`
//! allreduce per outer iteration** are identical to
//! [`crate::solvers::bcd`] (this loop is entered from `bcd::run` whenever
//! [`SolverOpts::reg`] is not the exact-L2 path); only the replicated
//! inner solve differs — [`crate::prox::solve::ca_prox_inner_solve`]
//! applies the regularizer's separable prox elementwise after
//! reconstructing each deferred step's gradient from the packed triangle.
//!
//! With [`SolverOpts::overlap`] the reduction runs through the
//! non-blocking allreduce while the overlap tensor and the `w` block
//! gather (both independent of the reduced values) are computed — same
//! payload, same reduction algorithm, bitwise-identical trajectory, still
//! exactly H/s collectives. NOTE: unlike the smooth `bcd::run_overlapped`,
//! this loop does **not** yet prefetch the next iteration's Gram under
//! the in-flight reduction, so the dominant flop cost is not hidden —
//! the Gram-prefetch pipeline for the prox loops is an open ROADMAP
//! item, not an implied property of `--overlap` here.
//!
//! Convergence metrics are the prox certificates ([`ProxRecord`]): the
//! penalized objective `P(w) = ‖y − Xᵀw‖²/(2n) + ψ(w)`, the Fenchel
//! duality gap from the scaled-residual dual candidate (the CoCoA-style
//! primal/dual certificate), the min-norm subgradient residual, and
//! nnz(w). One meter-excluded `(d+2)`-word allreduce per record.

use crate::comm::Communicator;
use crate::error::Result;
use crate::gram::ComputeBackend;
use crate::linalg::packed::packed_len;
use crate::matrix::Matrix;
use crate::metrics::{History, ProxRecord};
use crate::prox::{Reg, Regularizer};
use crate::sampling::{overlap_tensor_into, BlockSampler};
use crate::solvers::common::{
    cond_stride, flatten_blocks, metered_out, packed_gram_cond, should_record, PrimalOutput,
    SolverOpts,
};

/// Run CA-Prox-BCD on this rank's 1D-block-column shard (see
/// [`crate::solvers::bcd::run`] for the shard layout contract).
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    opts: &SolverOpts,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<PrimalOutput> {
    let d = a_loc.rows();
    let n_loc = a_loc.cols();
    opts.validate(d)?;
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let gl = packed_len(sb);
    let inv_n = 1.0 / n_global as f64;
    let lam = opts.lam;
    let reg = opts.reg;

    let mut w = vec![0.0; d];
    let mut alpha_loc = vec![0.0; n_loc];
    let mut history = History::default();

    // Hot-path scratch hoisted out of the loop (no per-iteration heap
    // traffic beyond the pooled collective buffers).
    let mut buf = vec![0.0; gl + sb]; // packed [G | r] allreduce payload
    let mut z = vec![0.0; n_loc];
    let mut w_blocks = vec![0.0; sb];
    let mut gram_scaled = vec![0.0; sb * sb];
    let mut idx_flat = vec![0usize; sb];
    let mut overlap = vec![0.0; s * s * b * b];

    let mut sampler = BlockSampler::new(d, opts.seed);

    record(
        &mut history,
        0,
        &w,
        &alpha_loc,
        y_loc,
        a_loc,
        n_global,
        lam,
        &reg,
        comm,
    )?;

    let outer = opts.outer_iters();
    let stride = cond_stride(sb, outer);
    'outer_loop: for k in 0..outer {
        let blocks = sampler.draw_blocks(s, b);
        flatten_blocks(&blocks, b, &mut idx_flat);

        // z = y − α (local slice), then the raw partial [G | r].
        for ((zi, yi), ai) in z.iter_mut().zip(y_loc).zip(&alpha_loc) {
            *zi = yi - ai;
        }
        {
            let (g_buf, r_buf) = buf.split_at_mut(gl);
            backend.gram_resid(a_loc, &idx_flat, &z, g_buf, r_buf)?;
        }

        // THE communication of this outer iteration — with overlap, the
        // tensor assembly and w gather hide behind the in-flight
        // reduction (they depend only on the shared-seed sample stream).
        if opts.overlap {
            let handle = comm.iallreduce_start(std::mem::take(&mut buf))?;
            overlap_tensor_into(&blocks, &mut overlap);
            gather_w_blocks(&blocks, b, &w, &mut w_blocks);
            buf = comm.iallreduce_wait(handle)?;
        } else {
            comm.allreduce_sum(&mut buf)?;
            overlap_tensor_into(&blocks, &mut overlap);
            gather_w_blocks(&blocks, b, &w, &mut w_blocks);
        }

        if opts.track_gram_cond && k % stride == 0 {
            // Condition of the smooth block system (1/n)·G + μ₂I
            // (μ₂ = the regularizer's quadratic weight; pure-L1 runs
            // report the raw data-term conditioning).
            let (_, mu2) = reg.weights(lam);
            history
                .gram_conds
                .push(packed_gram_cond(&buf, sb, inv_n, mu2, &mut gram_scaled));
        }

        // Replicated prox inner solve + deferred updates.
        let (g_buf, r_buf) = buf.split_at(gl);
        let deltas = backend
            .ca_prox_inner_solve(s, b, g_buf, r_buf, &w_blocks, &overlap, lam, inv_n, &reg)?;
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                w[row] += deltas[j * b + i];
            }
        }
        backend.alpha_update(a_loc, &idx_flat, &deltas, &mut alpha_loc)?;

        let h_now = (k + 1) * s;
        history.iters = h_now;
        if should_record(h_now, s, opts) || k + 1 == outer {
            record(
                &mut history,
                h_now,
                &w,
                &alpha_loc,
                y_loc,
                a_loc,
                n_global,
                lam,
                &reg,
                comm,
            )?;
            if let Some(tol) = opts.tol {
                if converged(&history, tol) {
                    break 'outer_loop;
                }
            }
        }
    }

    history.meter = *comm.meter();
    Ok(PrimalOutput {
        w,
        alpha_loc,
        history,
    })
}

fn gather_w_blocks(blocks: &[Vec<usize>], b: usize, w: &[f64], w_blocks: &mut [f64]) {
    for (j, blk) in blocks.iter().enumerate() {
        for (i, &row) in blk.iter().enumerate() {
            w_blocks[j * b + i] = w[row];
        }
    }
}

/// Stop once the certificate reaches `tol`: the duality gap when the
/// regularizer has one, the subgradient residual otherwise (`Reg::None`).
fn converged(history: &History, tol: f64) -> bool {
    match history.prox.last() {
        Some(r) if r.gap.is_finite() => r.gap <= tol,
        Some(r) => r.subgrad <= tol,
        None => false,
    }
}

/// Meter-excluded prox certificate: one `(d+2)`-word allreduce gathers
/// `[X·z | ‖z‖² | yᵀz]` (z = y − α distributed over ranks, w replicated),
/// from which the penalized objective, the Fenchel gap, the min-norm
/// subgradient residual, and nnz(w) all follow rank-locally.
#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w: &[f64],
    alpha_loc: &[f64],
    y_loc: &[f64],
    a_loc: &Matrix,
    n_global: usize,
    lam: f64,
    reg: &Reg,
    comm: &mut C,
) -> Result<()> {
    let d = w.len();
    let payload = metered_out(comm, |c| {
        let mut payload = vec![0.0; d + 2];
        let z: Vec<f64> = y_loc
            .iter()
            .zip(alpha_loc)
            .map(|(y, a)| y - a)
            .collect();
        a_loc.matvec(&z, &mut payload[..d])?;
        payload[d] = z.iter().map(|v| v * v).sum();
        payload[d + 1] = y_loc.iter().zip(&z).map(|(a, b)| a * b).sum();
        c.allreduce_sum(&mut payload)?;
        Ok(payload)
    })?;
    let (resid_sq, y_dot_z) = (payload[d], payload[d + 1]);
    let n = n_global as f64;
    // σ = Xz/n; the smooth data-term gradient is −σ.
    let sigma: Vec<f64> = payload[..d].iter().map(|v| v / n).collect();
    let smooth_grad: Vec<f64> = sigma.iter().map(|v| -v).collect();
    let pen_obj = resid_sq / (2.0 * n) + reg.penalty(w, lam);
    let gap = reg.duality_gap(w, &sigma, resid_sq, y_dot_z, n_global, lam);
    let subgrad = reg.subgrad_residual(&smooth_grad, w, lam);
    history.prox.push(ProxRecord {
        iter,
        pen_obj,
        gap,
        subgrad,
        nnz: Reg::nnz(w),
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::matrix::DenseMatrix;

    fn toy(d: usize, n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut st = seed | 1;
        let data: Vec<f64> = (0..d * n)
            .map(|_| {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                (st as f64 / u64::MAX as f64) - 0.5
            })
            .collect();
        let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
        let mut y = vec![0.0; n];
        // Sparse ground truth: only 2 active features.
        let mut w_star = vec![0.0; d];
        w_star[0] = 1.5;
        w_star[d / 2] = -2.0;
        x.matvec_t(&w_star, &mut y).unwrap();
        (x, y)
    }

    #[test]
    fn lasso_reaches_tiny_duality_gap() {
        let (x, y) = toy(8, 60, 5);
        let opts = SolverOpts {
            b: 1,
            s: 2,
            lam: 0.1,
            iters: 6000,
            seed: 3,
            record_every: 200,
            tol: Some(1e-10),
            reg: Reg::L1,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let out = run(&x, &y, 60, &opts, &mut comm, &mut be).unwrap();
        let last = out.history.prox.last().unwrap();
        assert!(last.gap <= 1e-10, "gap {}", last.gap);
        assert!(last.nnz < 8, "no sparsity: nnz {}", last.nnz);
    }

    #[test]
    fn prox_overlap_is_bitwise_identical_serial() {
        let (x, y) = toy(10, 40, 9);
        let mut opts = SolverOpts {
            b: 2,
            s: 3,
            lam: 0.05,
            iters: 60,
            seed: 4,
            record_every: 0,
            reg: Reg::Elastic { l1_ratio: 0.7 },
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&x, &y, 40, &opts, &mut comm, &mut be).unwrap().w;
        opts.overlap = true;
        let w2 = run(&x, &y, 40, &opts, &mut comm, &mut be).unwrap().w;
        assert_eq!(w1, w2, "overlap changed the prox trajectory");
    }

    #[test]
    fn prox_allreduce_count_is_h_over_s() {
        let (x, y) = toy(10, 40, 2);
        for s in [1usize, 4] {
            let opts = SolverOpts {
                b: 2,
                s,
                lam: 0.05,
                iters: 40,
                seed: 8,
                record_every: 0,
                reg: Reg::L1,
                ..Default::default()
            };
            let mut comm = SerialComm::new();
            let mut be = NativeBackend::new();
            let out = run(&x, &y, 40, &opts, &mut comm, &mut be).unwrap();
            assert_eq!(out.history.meter.allreduces as usize, 40 / s, "s={s}");
        }
    }
}
