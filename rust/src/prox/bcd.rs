//! CA-Prox-BCD — proximal primal block coordinate descent with the s-step
//! communication-avoiding unrolling.
//!
//! SPMD layout, sampling, Gram engine and the **one packed `[G|r]`
//! allreduce per outer iteration** are identical to
//! [`crate::solvers::bcd`] (this loop is entered from the
//! [`Session`](crate::engine::Session) whenever [`SolverOpts::reg`] is
//! not the exact-L2 path); only the replicated inner solve differs —
//! [`crate::prox::solve::ca_prox_inner_solve`] applies the regularizer's
//! separable prox elementwise after reconstructing each deferred step's
//! gradient from the packed triangle.
//!
//! The loop lives in the shared pipeline core
//! ([`crate::engine::drive`]). With [`SolverOpts::overlap`] the engine's
//! **prefetch schedule now applies here too**: the next iteration's Gram
//! (the dominant flop cost, a pure function of X and the shared-seed
//! sample stream) is computed under the in-flight `[G|r]` reduction,
//! alongside the overlap-tensor assembly and the `w` block gather —
//! closing the ROADMAP item that the prox loops hid only the cheap
//! tensor/gather work. Same payload, same reduction algorithm, still
//! exactly H/s collectives, bitwise-identical trajectory (asserted
//! against the frozen pre-engine loop in
//! `rust/tests/engine_equivalence.rs`).
//!
//! Convergence metrics are the prox certificates ([`ProxRecord`]): the
//! penalized objective `P(w) = ‖y − Xᵀw‖²/(2n) + ψ(w)`, the Fenchel
//! duality gap from the scaled-residual dual candidate (the CoCoA-style
//! primal/dual certificate), the min-norm subgradient residual, and
//! nnz(w). One meter-excluded `(d+2)`-word allreduce per record.

use crate::comm::Communicator;
use crate::engine::{drive, CaStep, Checkpoint, Sample};
use crate::error::Result;
use crate::gram::ComputeBackend;
use crate::linalg::packed::packed_len;
use crate::matrix::Matrix;
use crate::metrics::{History, ProxRecord};
use crate::prox::{Reg, Regularizer};
use crate::sampling::{overlap_tensor_into, BlockSampler};
use crate::solvers::common::{metered_out, PrimalOutput, SolverOpts};

/// Run CA-Prox-BCD on this rank's 1D-block-column shard (see
/// [`crate::solvers::bcd::run`] for the shard layout contract). This is
/// the engine entry the [`Session`](crate::engine::Session) dispatches to
/// for non-L2 regularizers on the matched primal layout.
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    opts: &SolverOpts,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<PrimalOutput> {
    let d = a_loc.rows();
    let n_loc = a_loc.cols();
    opts.validate(d)?;
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let mut history = History::default();
    let mut step = ProxBcdStep {
        a_loc,
        y_loc,
        n_global,
        backend,
        s,
        b,
        lam: opts.lam,
        inv_n: 1.0 / n_global as f64,
        gl: packed_len(sb),
        reg: opts.reg,
        sampler: BlockSampler::new(d, opts.seed),
        w: vec![0.0; d],
        alpha_loc: vec![0.0; n_loc],
        z: vec![0.0; n_loc],
        w_blocks: vec![0.0; sb],
        overlap: vec![0.0; s * s * b * b],
    };
    drive(&mut step, opts, comm, &mut history)?;
    Ok(PrimalOutput {
        w: step.w,
        alpha_loc: step.alpha_loc,
        history,
    })
}

/// The proximal primal method's per-iteration callbacks — identical to
/// [`BcdStep`](crate::solvers::bcd) except for the prox inner solve, the
/// μ₂-shifted conditioning probe, and the certificate records.
struct ProxBcdStep<'a> {
    a_loc: &'a Matrix,
    y_loc: &'a [f64],
    n_global: usize,
    backend: &'a mut dyn ComputeBackend,
    s: usize,
    b: usize,
    lam: f64,
    inv_n: f64,
    gl: usize,
    reg: Reg,
    sampler: BlockSampler,
    w: Vec<f64>,
    alpha_loc: Vec<f64>,
    z: Vec<f64>,
    w_blocks: Vec<f64>,
    overlap: Vec<f64>,
}

impl<C: Communicator> CaStep<C> for ProxBcdStep<'_> {
    fn payload_split(&self) -> (usize, usize) {
        (self.gl, self.s * self.b)
    }

    fn prefetch_gram(&self) -> bool {
        // The ROADMAP item closed by the engine port: the prox Gram is as
        // state-independent as the smooth one, so `--overlap` now
        // prefetches it under the in-flight reduction.
        true
    }

    fn sample(&mut self, _comm: &mut C, k: usize) -> Result<Sample> {
        Ok(Sample::flatten(
            k,
            self.sampler.draw_blocks(self.s, self.b),
            self.b,
        ))
    }

    fn local_gram(&mut self, _comm: &mut C, smp: &Sample, head: &mut [f64]) -> Result<()> {
        self.backend.gram_only(self.a_loc, &smp.idx, head)
    }

    fn local_state(&mut self, smp: &Sample, tail: &mut [f64]) -> Result<()> {
        // z = y − α (local slice), then r = Y_loc·z into the payload tail.
        for ((zi, yi), ai) in self.z.iter_mut().zip(self.y_loc).zip(&self.alpha_loc) {
            *zi = yi - ai;
        }
        self.backend.resid_only(self.a_loc, &smp.idx, &self.z, tail)
    }

    fn local_payload(
        &mut self,
        _comm: &mut C,
        smp: &Sample,
        head: &mut [f64],
        tail: &mut [f64],
    ) -> Result<()> {
        // Same-iteration gram + residual: one fused backend call, like
        // the pre-engine blocking loop.
        for ((zi, yi), ai) in self.z.iter_mut().zip(self.y_loc).zip(&self.alpha_loc) {
            *zi = yi - ai;
        }
        self.backend
            .gram_resid(self.a_loc, &smp.idx, &self.z, head, tail)
    }

    fn hidden_work(&mut self, smp: &Sample) -> Result<()> {
        overlap_tensor_into(&smp.blocks, &mut self.overlap);
        for (j, blk) in smp.blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                self.w_blocks[j * self.b + i] = self.w[row];
            }
        }
        Ok(())
    }

    fn cond_probe(&self) -> Option<(f64, f64)> {
        // Condition of the smooth block system (1/n)·G + μ₂I (μ₂ = the
        // regularizer's quadratic weight; pure-L1 runs report the raw
        // data-term conditioning).
        let (_, mu2) = self.reg.weights(self.lam);
        Some((self.inv_n, mu2))
    }

    fn inner_solve(&mut self, smp: &Sample, head: &[f64], tail: &[f64]) -> Result<Vec<f64>> {
        // Replicated prox inner solve (ProxStep span nests inside the
        // engine's InnerSolve span).
        let t0 = crate::trace::now();
        let out = self.backend.ca_prox_inner_solve(
            self.s,
            self.b,
            head,
            tail,
            &self.w_blocks,
            &self.overlap,
            self.lam,
            self.inv_n,
            &self.reg,
        );
        crate::trace::record(
            crate::trace::SpanKind::ProxStep,
            crate::trace::OpClass::Compute,
            smp.k as u64,
            (head.len() + tail.len()) as u64,
            t0,
        );
        out
    }

    fn apply(&mut self, smp: &Sample, deltas: &[f64]) -> Result<()> {
        for (j, blk) in smp.blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                self.w[row] += deltas[j * self.b + i];
            }
        }
        self.backend
            .alpha_update(self.a_loc, &smp.idx, deltas, &mut self.alpha_loc)
    }

    fn record(&mut self, comm: &mut C, history: &mut History, h_now: usize) -> Result<()> {
        record(
            history,
            h_now,
            &self.w,
            &self.alpha_loc,
            self.y_loc,
            self.a_loc,
            self.n_global,
            self.lam,
            &self.reg,
            comm,
        )
    }

    fn converged(&self, history: &History, tol: f64) -> bool {
        // Stop once the certificate reaches tol: the duality gap when the
        // regularizer has one, the subgradient residual otherwise
        // (`Reg::None`).
        match history.prox.last() {
            Some(r) if r.gap.is_finite() => r.gap <= tol,
            Some(r) => r.subgrad <= tol,
            None => false,
        }
    }

    fn ckpt_kind(&self) -> &'static str {
        "prox_bcd"
    }

    fn save_state(&self, ckpt: &mut Checkpoint) -> Result<()> {
        // Same state set as the smooth primal step: sampler RNG + the two
        // iterates (z / w_blocks / overlap are per-iteration scratch).
        ckpt.rng = self.sampler.rng_state().to_vec();
        ckpt.push_f64("w", &self.w);
        ckpt.push_f64("alpha_loc", &self.alpha_loc);
        Ok(())
    }

    fn restore_state(&mut self, ckpt: &Checkpoint) -> Result<()> {
        self.sampler.set_rng_state(ckpt.rng_words()?);
        ckpt.read_f64_into("w", &mut self.w)?;
        ckpt.read_f64_into("alpha_loc", &mut self.alpha_loc)
    }
}

/// Meter-excluded prox certificate: one `(d+2)`-word allreduce gathers
/// `[X·z | ‖z‖² | yᵀz]` (z = y − α distributed over ranks, w replicated),
/// from which the penalized objective, the Fenchel gap, the min-norm
/// subgradient residual, and nnz(w) all follow rank-locally.
#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w: &[f64],
    alpha_loc: &[f64],
    y_loc: &[f64],
    a_loc: &Matrix,
    n_global: usize,
    lam: f64,
    reg: &Reg,
    comm: &mut C,
) -> Result<()> {
    let d = w.len();
    let payload = metered_out(comm, |c| {
        let mut payload = vec![0.0; d + 2];
        let z: Vec<f64> = y_loc
            .iter()
            .zip(alpha_loc)
            .map(|(y, a)| y - a)
            .collect();
        a_loc.matvec(&z, &mut payload[..d])?;
        payload[d] = z.iter().map(|v| v * v).sum();
        payload[d + 1] = y_loc.iter().zip(&z).map(|(a, b)| a * b).sum();
        c.allreduce_sum(&mut payload)?;
        Ok(payload)
    })?;
    let (resid_sq, y_dot_z) = (payload[d], payload[d + 1]);
    let n = n_global as f64;
    // σ = Xz/n; the smooth data-term gradient is −σ.
    let sigma: Vec<f64> = payload[..d].iter().map(|v| v / n).collect();
    let smooth_grad: Vec<f64> = sigma.iter().map(|v| -v).collect();
    let pen_obj = resid_sq / (2.0 * n) + reg.penalty(w, lam);
    let gap = reg.duality_gap(w, &sigma, resid_sq, y_dot_z, n_global, lam);
    let subgrad = reg.subgrad_residual(&smooth_grad, w, lam);
    history.prox.push(ProxRecord {
        iter,
        pen_obj,
        gap,
        subgrad,
        nnz: Reg::nnz(w),
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::matrix::DenseMatrix;

    fn toy(d: usize, n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut st = seed | 1;
        let data: Vec<f64> = (0..d * n)
            .map(|_| {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                (st as f64 / u64::MAX as f64) - 0.5
            })
            .collect();
        let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
        let mut y = vec![0.0; n];
        // Sparse ground truth: only 2 active features.
        let mut w_star = vec![0.0; d];
        w_star[0] = 1.5;
        w_star[d / 2] = -2.0;
        x.matvec_t(&w_star, &mut y).unwrap();
        (x, y)
    }

    #[test]
    fn lasso_reaches_tiny_duality_gap() {
        let (x, y) = toy(8, 60, 5);
        let opts = SolverOpts {
            b: 1,
            s: 2,
            lam: 0.1,
            iters: 6000,
            seed: 3,
            record_every: 200,
            tol: Some(1e-10),
            reg: Reg::L1,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let out = run(&x, &y, 60, &opts, &mut comm, &mut be).unwrap();
        let last = out.history.prox.last().unwrap();
        assert!(last.gap <= 1e-10, "gap {}", last.gap);
        assert!(last.nnz < 8, "no sparsity: nnz {}", last.nnz);
    }

    #[test]
    fn prox_overlap_is_bitwise_identical_serial() {
        let (x, y) = toy(10, 40, 9);
        let mut opts = SolverOpts {
            b: 2,
            s: 3,
            lam: 0.05,
            iters: 60,
            seed: 4,
            record_every: 0,
            reg: Reg::Elastic { l1_ratio: 0.7 },
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&x, &y, 40, &opts, &mut comm, &mut be).unwrap().w;
        opts.overlap = true;
        let w2 = run(&x, &y, 40, &opts, &mut comm, &mut be).unwrap().w;
        assert_eq!(w1, w2, "overlap changed the prox trajectory");
    }

    #[test]
    fn prox_allreduce_count_is_h_over_s() {
        let (x, y) = toy(10, 40, 2);
        for s in [1usize, 4] {
            for overlap in [false, true] {
                let opts = SolverOpts {
                    b: 2,
                    s,
                    lam: 0.05,
                    iters: 40,
                    seed: 8,
                    record_every: 0,
                    overlap,
                    reg: Reg::L1,
                    ..Default::default()
                };
                let mut comm = SerialComm::new();
                let mut be = NativeBackend::new();
                let out = run(&x, &y, 40, &opts, &mut comm, &mut be).unwrap();
                assert_eq!(
                    out.history.meter.allreduces as usize,
                    40 / s,
                    "s={s} overlap={overlap}: the prefetch pipeline must \
                     keep exactly H/s collectives"
                );
            }
        }
    }
}
