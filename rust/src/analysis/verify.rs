//! Symbolic schedule extraction and verification for the solver suite.
//!
//! [`run_symbolic`] drives one solver configuration through
//! `engine::drive` with a [`SpecComm`] per rank and the zero-fill
//! [`MockBackend`] — ranks execute *sequentially in one thread*, which is
//! sound precisely because a `SpecComm` never depends on peer data. The
//! result is each rank's abstract collective schedule, checkable by
//! [`check_streams`](crate::analysis::checker::check_streams) without a
//! transport, a scheduler, or any risk of an actual deadlock.
//!
//! [`verify_all`] sweeps every method over {blocking, overlap} ×
//! P ∈ {1, 3, 4} (plus the early-tolerance-stop drain paths and a
//! two-level-topology neutrality pass) and checks each;
//! [`engine_schedule_runs`] reproduces the exact 48-config matrix
//! of `rust/tests/engine_equivalence.rs` so the per-rank schedules can be
//! pinned as the committed fixture
//! `rust/tests/fixtures/engine_schedules.tsv`.
//!
//! The symbolic runs set `track_gram_cond = false` where the dynamic
//! matrix uses `true`: condition tracking is a rank-local eigensolve with
//! no collectives (schedule-invariant), and the mock backend's zero Gram
//! would make its NaN handling the test subject instead of the schedule.

use crate::analysis::checker::check_streams;
use crate::analysis::mock::MockBackend;
use crate::analysis::spec::{SpecComm, SpecEvent};
use crate::comm::{Communicator, CostMeter, Topology};
use crate::coordinator::{partition_dual, partition_primal, partition_rows};
use crate::error::{Error, Result};
use crate::matrix::io::Dataset;
use crate::matrix::{DenseMatrix, Matrix};
use crate::metrics::Reference;
use crate::prox::Reg;
use crate::solvers::cocoa::CocoaOpts;
use crate::solvers::SolverOpts;

/// The solver configurations the verifier understands, by fixture name:
/// `bcd`, `bdcd`, `bcdrow`, `cocoa`, `prox_bcd`, `prox_bdcd`.
pub const METHODS: [&str; 6] = ["bcd", "bdcd", "bcdrow", "cocoa", "prox_bcd", "prox_bdcd"];

/// Matrix constants shared with `rust/tests/engine_equivalence.rs` — the
/// fixture schedules are only meaningful against that exact toy problem.
const LAM: f64 = 0.2;
const ITERS: usize = 16;
const SEED: u64 = 7;
const B: usize = 2;

/// One symbolic run: the per-rank event streams and meters of a solver
/// configuration, plus the fixture key that identifies it.
#[derive(Clone, Debug)]
pub struct ScheduleRun {
    /// Fixture method name (one of [`METHODS`]).
    pub method: &'static str,
    /// Fixture `s` column (`local_iters` for cocoa — wire-invariant).
    pub s: usize,
    /// Overlap schedule?
    pub overlap: bool,
    /// Rank count.
    pub p: usize,
    /// `streams[r]` = rank r's abstract event sequence.
    pub streams: Vec<Vec<SpecEvent>>,
    /// `meters[r]` = rank r's symbolic cost meter.
    pub meters: Vec<CostMeter>,
}

impl ScheduleRun {
    /// Rank-0 stream as fixture tokens.
    pub fn rank0_tokens(&self) -> Vec<String> {
        self.streams[0].iter().map(SpecEvent::token).collect()
    }
}

/// The d=12, n=48 toy problem of `rust/tests/engine_equivalence.rs`
/// (xorshift64 fill, planted 3-sparse `w*`). Values never influence a
/// schedule, but shapes (n_loc, d_loc, recv contracts) do — so the
/// symbolic runs use the exact dataset the dynamic matrix pins.
pub fn toy_dataset() -> Dataset {
    let (d, n) = (12usize, 48usize);
    let mut st = 0x5EED5EEDu64;
    let data: Vec<f64> = (0..d * n)
        .map(|_| {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            (st as f64 / u64::MAX as f64) - 0.5
        })
        .collect();
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
    let mut y = vec![0.0; n];
    let mut w_star = vec![0.0; d];
    w_star[0] = 1.5;
    w_star[d / 2] = -2.0;
    w_star[d - 1] = 0.75;
    if let Err(e) = x.matvec_t(&w_star, &mut y) {
        // Unreachable: shapes are constants; keep the path panic-free.
        debug_assert!(false, "toy matvec failed: {e}");
    }
    Dataset {
        name: "schedule-verify".into(),
        x,
        y,
    }
}

/// Dummy reference: triggers the same record schedule as a CG-computed
/// one (the record path branches on *presence*, never on values).
fn dummy_reference(d: usize) -> Reference {
    Reference {
        w_opt: vec![1.0; d],
        f_opt: 1.0,
    }
}

fn solver_opts(method: &'static str, s: usize, overlap: bool, tol: Option<f64>) -> SolverOpts {
    let reg = match method {
        "prox_bcd" | "prox_bdcd" => Reg::L1,
        _ => Reg::L2,
    };
    let mut b = SolverOpts::builder()
        .b(B)
        .s(s)
        .lam(LAM)
        .iters(ITERS)
        .seed(SEED)
        .record_every(4)
        .track_gram_cond(false)
        .overlap(overlap)
        .reg(reg);
    if let Some(t) = tol {
        b = b.tol(t);
    }
    b.build()
}

/// Drive one configuration symbolically: one [`SpecComm`] per rank, ranks
/// in sequence, mock compute. Returns the per-rank streams and meters.
///
/// `tol` enables the early-tolerance-stop drain path (requires a
/// reference, so it applies to the non-prox methods only).
pub fn run_symbolic(
    method: &'static str,
    s: usize,
    overlap: bool,
    p: usize,
    tol: Option<f64>,
) -> Result<ScheduleRun> {
    run_symbolic_with_topology(method, s, overlap, p, tol, Topology::Flat)
}

/// [`run_symbolic`] under an explicit wire topology. The topology feeds
/// the symbolic meter only (a two-level allreduce changes who sends what,
/// never the abstract op/tag/length schedule), so [`verify_all`] asserts
/// the streams stay bitwise identical to the flat runs.
pub fn run_symbolic_with_topology(
    method: &'static str,
    s: usize,
    overlap: bool,
    p: usize,
    tol: Option<f64>,
    topology: Topology,
) -> Result<ScheduleRun> {
    let ds = toy_dataset();
    let reference = dummy_reference(ds.d());
    let n = ds.n();
    let mut streams = Vec::with_capacity(p);
    let mut meters = Vec::with_capacity(p);
    for rank in 0..p {
        let mut comm = SpecComm::new(rank, p);
        comm.set_topology(topology);
        let mut be = MockBackend::new();
        match method {
            "bcd" | "prox_bcd" => {
                let shards = partition_primal(&ds, p)?;
                let sh = &shards[rank];
                let opts = solver_opts(method, s, overlap, tol);
                let rref = (method == "bcd").then_some(&reference);
                crate::solvers::bcd::run(&sh.a_loc, &sh.y_loc, n, &opts, rref, &mut comm, &mut be)?;
            }
            "bdcd" | "prox_bdcd" => {
                let shards = partition_dual(&ds, p)?;
                let sh = &shards[rank];
                let opts = solver_opts(method, s, overlap, tol);
                let rref = (method == "bdcd").then_some(&reference);
                crate::solvers::bdcd::run(
                    &sh.a_loc,
                    &sh.y,
                    sh.d_global,
                    sh.d_offset,
                    &opts,
                    rref,
                    &mut comm,
                    &mut be,
                )?;
            }
            "bcdrow" => {
                let shards = partition_rows(&ds, p)?;
                let sh = &shards[rank];
                let opts = solver_opts(method, s, overlap, tol);
                crate::solvers::bcd_row::run(
                    &sh.x_rows,
                    &sh.y_loc,
                    sh.d_global,
                    sh.d_offset,
                    &opts,
                    Some(&reference),
                    &mut comm,
                    &mut be,
                )?;
            }
            "cocoa" => {
                let shards = partition_primal(&ds, p)?;
                let sh = &shards[rank];
                let copts = CocoaOpts {
                    lam: LAM,
                    rounds: ITERS,
                    local_iters: s,
                    seed: SEED,
                    record_every: 4,
                    overlap,
                };
                crate::solvers::cocoa::run(
                    &sh.a_loc,
                    &sh.y_loc,
                    n,
                    &copts,
                    Some(&reference),
                    &mut comm,
                )?;
            }
            other => {
                return Err(Error::InvalidArg(format!(
                    "run_symbolic: unknown method `{other}` (expected one of {METHODS:?})"
                )))
            }
        }
        meters.push(*comm.meter());
        streams.push(comm.into_events());
    }
    Ok(ScheduleRun {
        method,
        s,
        overlap,
        p,
        streams,
        meters,
    })
}

/// Fixture `s`-axis per method (`local_iters` for cocoa), matching
/// `rust/tests/engine_equivalence.rs`.
pub fn s_axis(method: &str) -> [usize; 2] {
    if method == "cocoa" {
        [2, 8]
    } else {
        [1, 4]
    }
}

/// The exact 48-config matrix of `engine_equivalence.rs`: 6 methods ×
/// s-axis × {blocking, overlap} × P ∈ {1, 4}, in fixture row order.
pub fn engine_schedule_runs() -> Result<Vec<ScheduleRun>> {
    let mut runs = Vec::with_capacity(48);
    for method in METHODS {
        for s in s_axis(method) {
            for overlap in [false, true] {
                for p in [1usize, 4] {
                    runs.push(run_symbolic(method, s, overlap, p, None)?);
                }
            }
        }
    }
    Ok(runs)
}

/// Sweep every method × s-axis × {blocking, overlap} × P ∈ {1, 3, 4},
/// plus the early-tolerance-stop drain paths (matched prefetch pipeline
/// and the row layout's non-pipelined overlap) and a two-level-topology
/// neutrality pass (hierarchical wire routing must not perturb the
/// abstract schedule), and run [`check_streams`] on each. Returns the
/// number of configurations verified; the first violation aborts with
/// the checker's diagnosis.
///
/// P = 3 exercises the non-power-of-two allreduce fold/unfold, whose
/// wire counts are rank-dependent — lockstep of op/tag/length streams
/// must hold regardless.
pub fn verify_all() -> Result<usize> {
    let mut verified = 0usize;
    for method in METHODS {
        for s in s_axis(method) {
            for overlap in [false, true] {
                for p in [1usize, 3, 4] {
                    let run = run_symbolic(method, s, overlap, p, None)?;
                    check_streams(&run.streams).map_err(|e| {
                        annotate(e, method, s, overlap, p, "steady")
                    })?;
                    verified += 1;
                }
            }
        }
    }
    // Early-tolerance-stop drain paths: an infinite tolerance stops at
    // the first recorded boundary, exercising pipeline teardown (matched
    // prefetch look-ahead; bcdrow falls back to non-pipelined overlap
    // when a tolerance is set, draining its posted exchange in-loop).
    for method in ["bcd", "bdcd", "bcdrow"] {
        for p in [1usize, 3, 4] {
            let run = run_symbolic(method, 2, true, p, Some(f64::INFINITY))?;
            check_streams(&run.streams).map_err(|e| annotate(e, method, 2, true, p, "drain"))?;
            verified += 1;
        }
    }
    // Hierarchical topology neutrality: a two-level allreduce reroutes
    // wire traffic through node leaders but must leave the abstract
    // schedule untouched — same events, same tags, same lengths on every
    // rank — with only the meters moving. P = 3 with node_size = 2 gives
    // an unbalanced node (one leader with a member, one solo leader), the
    // shape most likely to break lockstep if topology ever leaked into
    // scheduling.
    for method in METHODS {
        let s = s_axis(method)[1];
        for p in [3usize, 4] {
            let flat = run_symbolic(method, s, true, p, None)?;
            let hier = run_symbolic_with_topology(
                method,
                s,
                true,
                p,
                None,
                Topology::TwoLevel { node_size: 2 },
            )?;
            check_streams(&hier.streams)
                .map_err(|e| annotate(e, method, s, true, p, "twolevel"))?;
            if hier.streams != flat.streams {
                return Err(annotate(
                    Error::Comm("two-level topology altered the abstract schedule".into()),
                    method,
                    s,
                    true,
                    p,
                    "twolevel",
                ));
            }
            for rank in 0..p {
                if hier.meters[rank].allreduces != flat.meters[rank].allreduces
                    || hier.meters[rank].all_to_alls != flat.meters[rank].all_to_alls
                {
                    return Err(annotate(
                        Error::Comm(format!(
                            "two-level topology changed collective counts on rank {rank}"
                        )),
                        method,
                        s,
                        true,
                        p,
                        "twolevel",
                    ));
                }
            }
            verified += 1;
        }
    }
    Ok(verified)
}

fn annotate(e: Error, method: &str, s: usize, overlap: bool, p: usize, phase: &str) -> Error {
    Error::Comm(format!(
        "[{method} s={s} overlap={overlap} p={p} {phase}] {e}"
    ))
}
