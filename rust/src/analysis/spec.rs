//! [`SpecComm`]: the symbolic communicator behind the schedule verifier.
//!
//! A `SpecComm` implements [`Communicator`] but moves **no data**: every
//! collective records one [`SpecEvent`] (op class, tag, payload lengths,
//! blocking vs start/wait, metered flag, poison state) and returns a
//! shape-correct zero payload. Driving a solver through `engine::drive`
//! with one `SpecComm` per rank therefore produces the rank's *abstract
//! schedule* — the exact op/tag/length sequence the thread transport
//! would execute — which [`crate::analysis::checker`] then verifies for
//! SPMD safety before any real transport runs it.
//!
//! Tag discipline mirrors [`ThreadComm`](crate::comm::ThreadComm): every
//! collective *entry* (blocking call or `i*_start`, including metered
//! diagnostic traffic and the P = 1 case) bumps the per-endpoint op
//! sequence; waits complete an existing tag and bump nothing. The meter
//! mirrors the thread transport too ([`expected_allreduce_sends`] for
//! allreduce wire counts, `P − 1` messages per personalized exchange),
//! so symbolic meters are comparable against `engine_meters.tsv`.

use std::collections::VecDeque;

use crate::comm::thread::expected_allreduce_sends;
use crate::comm::{
    expected_two_level_allreduce_sends, A2aState, AllToAllHandle, Communicator, CostMeter,
    HandleState, ReduceHandle, Topology,
};
use crate::error::{Error, Result};

/// The abstract operation one [`SpecEvent`] records.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpecOp {
    /// Blocking allreduce of `len` words.
    Allreduce {
        /// Payload length in f64 words.
        len: usize,
    },
    /// Non-blocking allreduce post of `len` words.
    IAllreduceStart {
        /// Payload length in f64 words.
        len: usize,
    },
    /// Completion of the in-flight allreduce that carried this event's tag.
    IAllreduceWait {
        /// Payload length of the completed operation.
        len: usize,
    },
    /// Broadcast of `len` words from `root`.
    Broadcast {
        /// Broadcasting rank.
        root: usize,
        /// Payload length in f64 words.
        len: usize,
    },
    /// Blocking personalized all-to-all (with receive-side contracts).
    AllToAll {
        /// Words sent to each rank (index = destination, self included).
        send_lens: Vec<usize>,
        /// Words expected from each rank (index = source, self included).
        recv_lens: Vec<usize>,
    },
    /// Non-blocking personalized all-to-all post.
    IAllToAllStart {
        /// Words sent to each rank.
        send_lens: Vec<usize>,
        /// Words expected from each rank.
        recv_lens: Vec<usize>,
    },
    /// Completion of the in-flight all-to-all carrying this event's tag.
    IAllToAllWait {
        /// Total words received across sources.
        recv_total: usize,
    },
    /// Barrier synchronization.
    Barrier,
    /// A collective refused because the group is poisoned.
    Refused,
}

impl SpecOp {
    /// Short class name for error messages and tokens.
    pub fn class(&self) -> &'static str {
        match self {
            SpecOp::Allreduce { .. } => "allreduce",
            SpecOp::IAllreduceStart { .. } => "iallreduce_start",
            SpecOp::IAllreduceWait { .. } => "iallreduce_wait",
            SpecOp::Broadcast { .. } => "broadcast",
            SpecOp::AllToAll { .. } => "all_to_all",
            SpecOp::IAllToAllStart { .. } => "iall_to_all_start",
            SpecOp::IAllToAllWait { .. } => "iall_to_all_wait",
            SpecOp::Barrier => "barrier",
            SpecOp::Refused => "refused",
        }
    }
}

/// One entry of a rank's abstract event stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpecEvent {
    /// Operation tag (the `ThreadComm` op-sequence number the transport
    /// would assign). Waits carry the tag of the operation they complete.
    pub tag: u64,
    /// True when the event was issued inside a
    /// [`metered_out`](crate::solvers::common::metered_out) scope —
    /// diagnostic traffic excluded from meters and traces.
    pub metered: bool,
    /// What was issued.
    pub op: SpecOp,
}

impl SpecEvent {
    /// Compact fixture token, e.g. `A3/5` (blocking 5-word allreduce, tag
    /// 3), `S4/44` / `W4` (non-blocking pair), `X7/24` / `Y8/96` / `Z8`
    /// (all-to-all: blocking / start / wait, `/total-recv-words`),
    /// `B2/12`, `R5` (barrier), with an `m` prefix for metered traffic.
    /// All-to-all send lengths are rank-dependent (Lemma 3 load
    /// imbalance) and deliberately absent — tokens must be identical on
    /// every rank; cross-rank send/recv consistency is the checker's job.
    pub fn token(&self) -> String {
        let m = if self.metered { "m" } else { "" };
        match &self.op {
            SpecOp::Allreduce { len } => format!("{m}A{}/{len}", self.tag),
            SpecOp::IAllreduceStart { len } => format!("{m}S{}/{len}", self.tag),
            SpecOp::IAllreduceWait { .. } => format!("{m}W{}", self.tag),
            SpecOp::Broadcast { root, len } => format!("{m}B{}/{root}/{len}", self.tag),
            SpecOp::AllToAll { recv_lens, .. } => {
                format!("{m}X{}/{}", self.tag, recv_lens.iter().sum::<usize>())
            }
            SpecOp::IAllToAllStart { recv_lens, .. } => {
                format!("{m}Y{}/{}", self.tag, recv_lens.iter().sum::<usize>())
            }
            SpecOp::IAllToAllWait { .. } => format!("{m}Z{}", self.tag),
            SpecOp::Barrier => format!("{m}R{}", self.tag),
            SpecOp::Refused => format!("{m}P{}", self.tag),
        }
    }
}

/// Symbolic communicator: one per (virtual) rank. Ranks run sequentially
/// in the same thread — legal because no event depends on peer data.
#[derive(Debug)]
pub struct SpecComm {
    rank: usize,
    size: usize,
    op_seq: u64,
    meter: CostMeter,
    events: Vec<SpecEvent>,
    /// In-flight allreduces, FIFO: (tag, len).
    pending_ar: VecDeque<(u64, usize)>,
    /// In-flight all-to-alls, FIFO: (tag, recv_lens).
    pending_a2a: VecDeque<(u64, Vec<usize>)>,
    poisoned: bool,
    /// Fault injection: when set, `begin_op` stops advancing the op
    /// sequence, so every subsequent collective reuses the current tag —
    /// the aliasing scenario invariant (c) must catch.
    freeze_tags: bool,
    /// Fault injection: constant added to every issued tag, used to
    /// simulate a rank whose tag stream diverged from its peers.
    tag_skew: u64,
    /// Wire topology the symbolic meter models. Events never depend on
    /// it — a two-level allreduce is schedule-invariant — but the
    /// metered send counts switch to the hierarchical closed form.
    topology: Topology,
}

impl SpecComm {
    /// A fresh symbolic endpoint for `rank` of `size`.
    pub fn new(rank: usize, size: usize) -> Self {
        assert!(size > 0 && rank < size, "rank {rank} outside group of {size}");
        SpecComm {
            rank,
            size,
            op_seq: 0,
            meter: CostMeter::default(),
            events: Vec::new(),
            pending_ar: VecDeque::new(),
            pending_a2a: VecDeque::new(),
            poisoned: false,
            freeze_tags: false,
            tag_skew: 0,
            topology: Topology::Flat,
        }
    }

    /// The recorded event stream so far.
    pub fn events(&self) -> &[SpecEvent] {
        &self.events
    }

    /// Consume the endpoint, returning its full event stream.
    pub fn into_events(self) -> Vec<SpecEvent> {
        self.events
    }

    /// Fixture-token rendering of the whole stream.
    pub fn tokens(&self) -> Vec<String> {
        self.events.iter().map(SpecEvent::token).collect()
    }

    /// Fault injection: freeze the tag sequence so later collectives
    /// alias the current tag (exercises checker invariant (c)).
    pub fn set_freeze_tags(&mut self, freeze: bool) {
        self.freeze_tags = freeze;
    }

    /// Fault injection: skew every subsequently issued tag by `skew`
    /// (exercises the cross-rank divergence check, invariant (a)).
    pub fn set_tag_skew(&mut self, skew: u64) {
        self.tag_skew = skew;
    }

    /// Poison the endpoint: every later collective records a `Refused`
    /// event and errors, mirroring the thread transport's sticky group
    /// poison. Returns the error the refusing collective would surface.
    pub fn poison(&mut self, msg: &str) -> Error {
        self.poisoned = true;
        Error::Comm(format!("group poisoned: {msg}"))
    }

    /// Mirror of `ThreadComm::begin_op`: every collective entry (blocking
    /// or start, metered or not, any P) takes the next tag.
    fn begin_op(&mut self) -> u64 {
        if !self.freeze_tags {
            self.op_seq += 1;
        }
        self.op_seq + self.tag_skew
    }

    fn push(&mut self, tag: u64, op: SpecOp) {
        self.events.push(SpecEvent {
            tag,
            metered: crate::trace::paused(),
            op,
        });
    }

    /// Record a refused collective and return the sticky poison error.
    fn refuse(&mut self, what: &'static str) -> Error {
        let tag = self.op_seq + self.tag_skew;
        self.push(tag, SpecOp::Refused);
        Error::Comm(format!(
            "group poisoned: rank {} refused {what} (endpoint poisoned earlier)",
            self.rank
        ))
    }

    fn meter_allreduce_entry(&mut self, len: usize) {
        self.meter.allreduces += 1;
        if self.size > 1 {
            let (msgs, words) = match self.topology {
                Topology::Flat => expected_allreduce_sends(self.size, self.rank, len),
                Topology::TwoLevel { node_size } => {
                    expected_two_level_allreduce_sends(self.size, node_size, self.rank, len)
                }
            };
            // Send/receive symmetry holds per rank under both
            // topologies: a member's fan-in send is answered by one
            // fan-out receive, and a leader's fan-in receives match its
            // fan-out sends around a symmetric leader exchange.
            self.meter.msgs += msgs;
            self.meter.words += words;
            self.meter.recv_msgs += msgs;
            self.meter.recv_words += words;
        }
    }

    fn meter_a2a_entry(&mut self, send_lens: &[usize], recv_lens: &[usize]) {
        self.meter.all_to_alls += 1;
        if self.size > 1 {
            self.meter.msgs += (self.size - 1) as u64;
            self.meter.recv_msgs += (self.size - 1) as u64;
            for (q, &len) in send_lens.iter().enumerate() {
                if q != self.rank {
                    self.meter.words += len as u64;
                }
            }
            for (q, &len) in recv_lens.iter().enumerate() {
                if q != self.rank {
                    self.meter.recv_words += len as u64;
                }
            }
        }
    }
}

impl Communicator for SpecComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn allreduce_sum(&mut self, buf: &mut [f64]) -> Result<()> {
        if self.poisoned {
            return Err(self.refuse("allreduce_sum"));
        }
        let tag = self.begin_op();
        self.meter_allreduce_entry(buf.len());
        self.push(tag, SpecOp::Allreduce { len: buf.len() });
        // Identity reduction: the caller's local contribution stands in
        // for the group sum — values never influence the schedule.
        Ok(())
    }

    fn iallreduce_start(&mut self, buf: Vec<f64>) -> Result<ReduceHandle> {
        if self.poisoned {
            return Err(self.refuse("iallreduce_start"));
        }
        let tag = self.begin_op();
        self.meter_allreduce_entry(buf.len());
        self.push(tag, SpecOp::IAllreduceStart { len: buf.len() });
        self.pending_ar.push_back((tag, buf.len()));
        Ok(ReduceHandle {
            buf,
            state: HandleState::Done,
        })
    }

    fn iallreduce_wait(&mut self, handle: ReduceHandle) -> Result<Vec<f64>> {
        if self.poisoned {
            return Err(self.refuse("iallreduce_wait"));
        }
        let Some((tag, len)) = self.pending_ar.pop_front() else {
            return Err(Error::Comm(format!(
                "schedule violation: rank {} waited on an allreduce with none in flight",
                self.rank
            )));
        };
        self.meter.collective_waits += 1;
        self.push(tag, SpecOp::IAllreduceWait { len });
        Ok(handle.buf)
    }

    fn broadcast(&mut self, root: usize, buf: &mut [f64]) -> Result<()> {
        if self.poisoned {
            return Err(self.refuse("broadcast"));
        }
        let tag = self.begin_op();
        self.push(
            tag,
            SpecOp::Broadcast {
                root,
                len: buf.len(),
            },
        );
        Ok(())
    }

    fn all_to_all(&mut self, send: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        if self.poisoned {
            return Err(self.refuse("all_to_all"));
        }
        if send.len() != self.size {
            return Err(self.poison(&format!(
                "all_to_all: rank {} supplied {} send buffers for {} ranks",
                self.rank,
                send.len(),
                self.size
            )));
        }
        // No receive-side contract: symbolically echo the send shape
        // (the self-exchange identity), recording it as both directions.
        let lens: Vec<usize> = send.iter().map(Vec::len).collect();
        let tag = self.begin_op();
        self.meter_a2a_entry(&lens, &lens);
        self.push(
            tag,
            SpecOp::AllToAll {
                send_lens: lens.clone(),
                recv_lens: lens,
            },
        );
        Ok(send)
    }

    fn all_to_all_expect(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
    ) -> Result<Vec<Vec<f64>>> {
        if self.poisoned {
            return Err(self.refuse("all_to_all_expect"));
        }
        if send.len() != self.size || recv_lens.len() != self.size {
            return Err(self.poison(&format!(
                "all_to_all_expect: rank {} supplied {} send buffers / {} receive \
                 lengths for {} ranks",
                self.rank,
                send.len(),
                recv_lens.len(),
                self.size
            )));
        }
        let send_lens: Vec<usize> = send.iter().map(Vec::len).collect();
        let tag = self.begin_op();
        self.meter_a2a_entry(&send_lens, recv_lens);
        self.push(
            tag,
            SpecOp::AllToAll {
                send_lens,
                recv_lens: recv_lens.to_vec(),
            },
        );
        // Shape-correct zero payloads honoring the receive contract (the
        // default trait impl would echo the sends and fail its own
        // length validation).
        Ok(recv_lens.iter().map(|&l| vec![0.0; l]).collect())
    }

    fn iall_to_all_start(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
    ) -> Result<AllToAllHandle> {
        if self.poisoned {
            return Err(self.refuse("iall_to_all_start"));
        }
        if send.len() != self.size || recv_lens.len() != self.size {
            return Err(self.poison(&format!(
                "iall_to_all_start: rank {} supplied {} send buffers / {} receive \
                 lengths for {} ranks",
                self.rank,
                send.len(),
                recv_lens.len(),
                self.size
            )));
        }
        let send_lens: Vec<usize> = send.iter().map(Vec::len).collect();
        let tag = self.begin_op();
        self.meter_a2a_entry(&send_lens, recv_lens);
        self.push(
            tag,
            SpecOp::IAllToAllStart {
                send_lens,
                recv_lens: recv_lens.to_vec(),
            },
        );
        self.pending_a2a.push_back((tag, recv_lens.to_vec()));
        Ok(AllToAllHandle {
            state: A2aState::Ready(Vec::new()),
        })
    }

    fn iall_to_all_wait(&mut self, _handle: AllToAllHandle) -> Result<Vec<Vec<f64>>> {
        if self.poisoned {
            return Err(self.refuse("iall_to_all_wait"));
        }
        let Some((tag, recv_lens)) = self.pending_a2a.pop_front() else {
            return Err(Error::Comm(format!(
                "schedule violation: rank {} waited on an all-to-all with none in flight",
                self.rank
            )));
        };
        self.meter.collective_waits += 1;
        self.push(
            tag,
            SpecOp::IAllToAllWait {
                recv_total: recv_lens.iter().sum(),
            },
        );
        Ok(recv_lens.iter().map(|&l| vec![0.0; l]).collect())
    }

    fn barrier(&mut self) -> Result<()> {
        if self.poisoned {
            return Err(self.refuse("barrier"));
        }
        let tag = self.begin_op();
        self.push(tag, SpecOp::Barrier);
        Ok(())
    }

    fn set_topology(&mut self, topology: Topology) {
        self.topology = topology;
    }

    fn meter(&self) -> &CostMeter {
        &self.meter
    }

    fn meter_mut(&mut self) -> &mut CostMeter {
        &mut self.meter
    }
}
