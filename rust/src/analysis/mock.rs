//! [`MockBackend`]: the trivial compute backend behind the schedule
//! verifier.
//!
//! Schedules must not depend on data values — that is exactly the SPMD
//! property the verifier proves — so the symbolic runs replace every
//! kernel with a zero fill. Pooled buffers are reused across iterations,
//! so each fill overwrites the *full* output slice rather than assuming
//! zeroed storage. The two prox solves are overridden as well: the
//! default trait implementations estimate a Lipschitz step from the Gram
//! diagonal, which is zero here and would divide by zero.

use crate::error::Result;
use crate::gram::ComputeBackend;
use crate::matrix::Matrix;

/// Compute backend whose every kernel returns zeros of the right shape.
#[derive(Debug, Default)]
pub struct MockBackend;

impl MockBackend {
    /// A stateless mock backend.
    pub fn new() -> Self {
        MockBackend
    }
}

impl ComputeBackend for MockBackend {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn gram_resid(
        &mut self,
        _a: &Matrix,
        _idx: &[usize],
        _z: &[f64],
        g: &mut [f64],
        r: &mut [f64],
    ) -> Result<()> {
        g.fill(0.0);
        r.fill(0.0);
        Ok(())
    }

    fn ca_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        _g_raw: &[f64],
        _r_raw: &[f64],
        _w_blocks: &[f64],
        _overlap: &[f64],
        _lam: f64,
        _inv_n: f64,
    ) -> Result<Vec<f64>> {
        Ok(vec![0.0; s * b])
    }

    fn ca_dual_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        _g_raw: &[f64],
        _r_raw: &[f64],
        _a_blocks: &[f64],
        _y_blocks: &[f64],
        _overlap: &[f64],
        _lam: f64,
        _inv_n: f64,
    ) -> Result<Vec<f64>> {
        Ok(vec![0.0; s * b])
    }

    fn ca_prox_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        _g_raw: &[f64],
        _r_raw: &[f64],
        _w_blocks: &[f64],
        _overlap: &[f64],
        _lam: f64,
        _inv_n: f64,
        _reg: &crate::prox::Reg,
    ) -> Result<Vec<f64>> {
        Ok(vec![0.0; s * b])
    }

    fn ca_prox_dual_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        _g_raw: &[f64],
        _r_raw: &[f64],
        _a_blocks: &[f64],
        _y_blocks: &[f64],
        _overlap: &[f64],
        _lam: f64,
        _inv_n: f64,
        _reg: &crate::prox::Reg,
    ) -> Result<Vec<f64>> {
        Ok(vec![0.0; s * b])
    }

    fn alpha_update(
        &mut self,
        _a: &Matrix,
        _idx: &[usize],
        _d: &[f64],
        _acc: &mut [f64],
    ) -> Result<()> {
        Ok(())
    }
}
