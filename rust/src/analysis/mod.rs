//! Static analysis for the SPMD solver suite: a symbolic schedule
//! verifier and a project-local lint pass.
//!
//! Communication bugs in this codebase are not value bugs — they are
//! *schedule* bugs: a rank that skips a collective, a wait that never
//! happens, a tag reused while its operation is still in flight, a
//! poisoned group that half-continues. None of those are visible to unit
//! tests of the math, and on the thread transport they surface as hangs
//! or heisenbugs. This module attacks them statically, in two layers:
//!
//! * **Schedule verification** ([`spec`], [`checker`], [`verify`],
//!   [`mock`]) — run every solver through `engine::drive` against a
//!   [`SpecComm`]: a [`Communicator`](crate::comm::Communicator) that
//!   moves no data and records each rank's abstract event stream (op
//!   class, tag, payload length, blocking vs start/wait, poison state).
//!   [`check_streams`] then proves lockstep, handle hygiene, tag
//!   uniqueness, and poison domination over the per-rank streams. Because
//!   schedules are data-independent (the property being proved), ranks
//!   can run sequentially in one thread with a zero-fill [`MockBackend`]
//!   — no transport, no threads, no flakiness.
//! * **Lint** ([`lint`]) — a stdlib-only token-level pass over
//!   `rust/src/**` enforcing the project's SPMD hygiene rules: lexical
//!   start/wait pairing, no `unwrap`/`expect`/`panic!` in non-test
//!   library paths, collectives called only from approved seams, and no
//!   allocation or `Instant::now` in the traced hot loop outside
//!   approved sites. The audited remainder is frozen in an allowlist
//!   that ratchets both ways. Run it as `cargo run --bin ca_lint`.
//!
//! Tests in `rust/tests/analysis.rs` pin the full 48-config schedule
//! matrix of `engine_equivalence.rs` to the committed fixture
//! `rust/tests/fixtures/engine_schedules.tsv` and demonstrate that
//! seeded faults (skipped wait, rank-divergent collective, tag aliasing,
//! post-poison traffic) are caught with actionable errors.

#![warn(missing_docs)]

pub mod checker;
pub mod lint;
pub mod mock;
pub mod spec;
pub mod verify;

pub use checker::check_streams;
pub use lint::{run_lint, LintReport, Violation};
pub use mock::MockBackend;
pub use spec::{SpecComm, SpecEvent, SpecOp};
pub use verify::{
    engine_schedule_runs, run_symbolic, run_symbolic_with_topology, verify_all, ScheduleRun,
    METHODS,
};
