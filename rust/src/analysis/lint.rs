//! `ca_lint`: a stdlib-only, token-level hygiene lint over `rust/src/**`.
//!
//! Clippy cannot see the project's SPMD discipline, so this pass encodes
//! it directly. Four rules, all scoped to **library** code — `main.rs`
//! and `bin/**` are driver surfaces and exempt, and `#[cfg(test)]` items
//! are stripped before scanning:
//!
//! * **`no-unwrap`** — `.unwrap(` / `.expect(` / `panic!(` are forbidden
//!   in library paths: on the thread transport a panicking rank strands
//!   its peers mid-collective, so fallible paths must return `Error` or
//!   poison the group. The audited remainder (seed parsing after
//!   validation, test-only generators, the deliberate panic propagation
//!   in `run_spmd`'s join) is frozen in [`ALLOW`].
//! * **`start-wait`** — within each file, `iallreduce_start` /
//!   `iallreduce_wait` (and the all-to-all pair) must appear the same
//!   number of times: a lexical proxy for "no handle escapes the file
//!   that created it". Files that intentionally split (the row solver
//!   posts one exchange and drains it at two sites) are frozen with
//!   their imbalance.
//! * **`collective-seam`** — dotted collective calls outside `comm/`,
//!   `engine/`, and `analysis/` are confined to two seams: the
//!   `metered_out` closure parameter (receiver `c`, the metrics seam)
//!   and the frozen direct-call sites (the row solver's exchange, the
//!   CG baseline). Everything else must route communication through
//!   `engine::drive`, where schedules are verified.
//! * **`hot-loop`** — `Instant::now(` is free only in the clock-owner
//!   files (`trace/mod.rs`, `telemetry/mod.rs`, `util/bench.rs`,
//!   `coordinator/driver.rs`);
//!   everywhere else each file's count must be budgeted in [`ALLOW`]
//!   under the `instant-now` rule (currently just the thread
//!   transport's receive-deadline clock). Allocation tokens (`vec![`,
//!   `Vec::with_capacity(`, `Vec::new(`, `.to_vec(`) in the traced hot
//!   loop `engine/step.rs` are budgeted at their audited count —
//!   steady-state iterations must reuse pooled buffers.
//!
//! The scanner strips `//` and nested `/* */` comments, string / raw
//! string / char literals (lifetime-aware), and `#[cfg(test)]`-gated
//! items before matching, so rule needles can be written as plain
//! literals without self-matching.
//!
//! [`ALLOW`] ratchets **both ways**: a count drifting above its frozen
//! value is a violation, and so is a stale entry whose count dropped —
//! shrink the allowlist instead of leaving dead exemptions. The gate
//! test `lint_is_clean_and_allowlist_is_frozen` in
//! `rust/tests/analysis.rs` keeps CI honest, and the `ca_lint` binary
//! runs the same pass from the command line.

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// The audited, frozen exemptions: `(rule, file, count)`. Counts are
/// exact — any drift in either direction is a violation.
pub const ALLOW: &[(&str, &str, usize)] = &[
    // Deliberate panic propagation when joining SPMD worker threads: a
    // panicked worker already tore down the group, and swallowing the
    // join error would hide the original panic message.
    ("no-unwrap", "comm/thread.rs", 2),
    // Seed/shape parsing immediately after explicit validation.
    ("no-unwrap", "config.rs", 2),
    // Eigenvalue sort over values already filtered finite.
    ("no-unwrap", "linalg/cond.rs", 1),
    ("no-unwrap", "matrix/csr.rs", 1),
    // Synthetic dataset generators (library API, but test/bench only).
    ("no-unwrap", "matrix/gen.rs", 4),
    ("no-unwrap", "metrics.rs", 2),
    ("no-unwrap", "trace/analysis.rs", 1),
    ("no-unwrap", "util/bench.rs", 2),
    ("no-unwrap", "util/proptest.rs", 3),
    // The row solver posts one look-ahead exchange and drains it at two
    // sites (pipelined and non-pipelined acquire): one start, two waits.
    ("start-wait", "solvers/bcd_row.rs", 1),
    // Direct collective calls that predate `engine::drive` seams: the
    // row solver's all-to-all exchange (4 sites) and the CG baseline's
    // two allreduces. New solvers must route through the engine.
    ("collective-seam", "solvers/bcd_row.rs", 4),
    ("collective-seam", "solvers/cg.rs", 2),
    // The telemetry aggregation allreduce (PR 9): one metered-out,
    // trace- and telemetry-paused collective that merges per-rank
    // registries on the record cadence. It runs at a schedule-verified
    // call site inside `engine::drive`'s boundary hook, so lockstep
    // order is preserved; it cannot route through the engine seam
    // itself because it ships registry blocks, not solver payloads.
    ("collective-seam", "telemetry/aggregate.rs", 1),
    // Audited allocation tokens in the engine hot loop: setup-phase
    // buffer pools and per-run history vectors, none per-iteration.
    ("hot-loop-alloc", "engine/step.rs", 7),
    // The receive-deadline clock (PR 8): one read to arm the expiry when
    // a deadline is set, one inside the poll loop to compute the budget
    // remaining. Both sit on the already-blocking recv path — never on
    // the deadline-free fast path — so traced schedules stay
    // deterministic when no timeout is configured.
    ("instant-now", "comm/thread.rs", 2),
    // The process transport's receive-deadline clock (PR 10): same
    // shape as the thread transport — arm the expiry, then budget the
    // remaining wait inside the inbox poll loop. Deadline-free runs
    // never touch either site.
    ("instant-now", "comm/process/mod.rs", 2),
    // Bootstrap handshake deadlines: rendezvous accept and worker dial
    // both bound the connection phase (30 s) so a missing rank turns
    // into an error instead of a hung launcher. Runs once per process
    // at startup, never on the data path.
    ("instant-now", "comm/process/rendezvous.rs", 2),
];

/// Collective method names whose call sites rule `collective-seam`
/// confines to approved modules and seams.
const COLLECTIVES: [&str; 9] = [
    "allreduce_sum",
    "iallreduce_start",
    "iallreduce_wait",
    "broadcast",
    "all_to_all_expect",
    "iall_to_all_start",
    "iall_to_all_wait",
    "barrier",
    "all_to_all",
];

/// Files (relative to the source root) that **own** a wall clock and may
/// call `Instant::now(` freely: the tracer clock, the telemetry epoch
/// clock, the bench harness, and the driver's wall-time report. Any
/// other file's calls are budgeted per-file in [`ALLOW`] under the
/// `instant-now` rule.
const INSTANT_OK: [&str; 4] = [
    "trace/mod.rs",
    "telemetry/mod.rs",
    "util/bench.rs",
    "coordinator/driver.rs",
];

/// Allocation tokens budgeted in the engine hot loop.
const ALLOC_TOKENS: [&str; 4] = ["vec![", "Vec::with_capacity(", "Vec::new(", ".to_vec("];

/// One lint finding: which rule, which file, and what went wrong.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Rule identifier (`no-unwrap`, `start-wait`, `collective-seam`,
    /// `instant-now`, `hot-loop-alloc`, or `allowlist`).
    pub rule: &'static str,
    /// File path relative to the scanned source root.
    pub file: String,
    /// Human-readable diagnosis with the measured numbers.
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}: {}", self.rule, self.file, self.detail)
    }
}

/// Outcome of a full lint pass.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    /// Library `.rs` files scanned (bin surfaces excluded).
    pub files_scanned: usize,
    /// All violations, in deterministic (rule, file) order.
    pub violations: Vec<Violation>,
    /// Allowlist entries whose frozen count matched exactly.
    pub allow_matched: usize,
}

impl LintReport {
    /// True when the pass found nothing — the CI gate condition.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

impl fmt::Display for LintReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "ca_lint: {} files scanned, {} allowlist entries matched, {} violation(s)",
            self.files_scanned,
            self.allow_matched,
            self.violations.len()
        )?;
        for v in &self.violations {
            writeln!(f, "  {v}")?;
        }
        Ok(())
    }
}

/// Run the full lint pass over `src_root` (normally `rust/src`).
///
/// Returns `Err` only for IO problems (unreadable tree); lint findings
/// are reported in the [`LintReport`], clean or not.
pub fn run_lint(src_root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();

    let mut report = LintReport::default();
    // Measured (rule, file) -> count, reconciled against ALLOW below.
    let mut measured: BTreeMap<(&'static str, String), usize> = BTreeMap::new();

    for path in &files {
        let rel = relative_name(src_root, path)?;
        if rel == "main.rs" || rel.starts_with("bin/") {
            continue; // driver surfaces: exempt from library rules
        }
        let raw = std::fs::read_to_string(path)?;
        let text = strip_cfg_test(&strip_source(&raw));
        report.files_scanned += 1;

        // no-unwrap
        let unwraps = count_substr(&text, ".unwrap(")
            + count_substr(&text, ".expect(")
            + count_substr(&text, "panic!(");
        if unwraps > 0 {
            measured.insert(("no-unwrap", rel.clone()), unwraps);
        }

        // start-wait lexical pairing
        let imbalance = count_ident(&text, "iallreduce_start")
            .abs_diff(count_ident(&text, "iallreduce_wait"))
            + count_ident(&text, "iall_to_all_start")
                .abs_diff(count_ident(&text, "iall_to_all_wait"));
        if imbalance > 0 {
            measured.insert(("start-wait", rel.clone()), imbalance);
        }

        // collective-seam (outside the modules that own communication)
        if !rel.starts_with("comm/") && !rel.starts_with("engine/") && !rel.starts_with("analysis/")
        {
            let calls = seam_calls(&text);
            if calls > 0 {
                measured.insert(("collective-seam", rel.clone()), calls);
            }
        }

        // hot-loop: Instant::now outside the clock-owner files goes
        // through the frozen budget like every other audited exemption
        // (e.g. the thread transport's receive-deadline clock).
        if !INSTANT_OK.contains(&rel.as_str()) {
            let nows = count_substr(&text, "Instant::now(");
            if nows > 0 {
                measured.insert(("instant-now", rel.clone()), nows);
            }
        }

        // hot-loop: allocation budget in the engine inner loop
        if rel == "engine/step.rs" {
            let allocs: usize = ALLOC_TOKENS.iter().map(|t| count_substr(&text, t)).sum();
            if allocs > 0 {
                measured.insert(("hot-loop-alloc", rel.clone()), allocs);
            }
        }
    }

    // Reconcile measured counts against the frozen allowlist, both ways.
    for ((rule, file), count) in &measured {
        match ALLOW
            .iter()
            .find(|(r, f, _)| r == rule && f == file)
            .map(|(_, _, frozen)| *frozen)
        {
            Some(frozen) if frozen == *count => report.allow_matched += 1,
            Some(frozen) => report.violations.push(Violation {
                rule,
                file: file.clone(),
                detail: format!(
                    "count {count} != frozen allowlist count {frozen}; fix the new \
                     site(s) or re-audit and update ALLOW in analysis/lint.rs"
                ),
            }),
            None => report.violations.push(Violation {
                rule,
                file: file.clone(),
                detail: format!(
                    "{count} occurrence(s) and no allowlist entry; fix the site(s) \
                     or audit them into ALLOW in analysis/lint.rs"
                ),
            }),
        }
    }
    for (rule, file, frozen) in ALLOW {
        let have = measured
            .get(&(*rule, (*file).to_string()))
            .copied()
            .unwrap_or(0);
        if have == 0 {
            report.violations.push(Violation {
                rule: "allowlist",
                file: (*file).to_string(),
                detail: format!(
                    "stale entry ({rule}, frozen {frozen}): the file now measures 0 — \
                     delete the entry so the ratchet keeps its teeth"
                ),
            });
        }
    }

    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries = Vec::new();
    for e in std::fs::read_dir(dir)? {
        entries.push(e?);
    }
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().map(|x| x == "rs") == Some(true) {
            out.push(p);
        }
    }
    Ok(())
}

fn relative_name(root: &Path, path: &Path) -> Result<String> {
    let rel = path.strip_prefix(root).map_err(|_| {
        Error::Runtime(format!(
            "lint: {} is not under the scanned root {}",
            path.display(),
            root.display()
        ))
    })?;
    let parts: Vec<String> = rel
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect();
    Ok(parts.join("/"))
}

/// Replace comments and string/char literals with blanks (newlines are
/// preserved so stripped text keeps its line structure).
fn strip_source(text: &str) -> String {
    let b = text.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        let nxt = if i + 1 < b.len() { b[i + 1] } else { 0 };
        if c == b'/' && nxt == b'/' {
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
        } else if c == b'/' && nxt == b'*' {
            // Block comments nest in Rust.
            i += 2;
            let mut depth = 1usize;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        out.push(b'\n');
                    }
                    i += 1;
                }
            }
        } else if c == b'r' && (nxt == b'"' || nxt == b'#') {
            // Raw string r"..." / r#"..."# (raw identifiers like r#type
            // have no quote after the hashes and fall through).
            let mut h = i + 1;
            while h < b.len() && b[h] == b'#' {
                h += 1;
            }
            if h < b.len() && b[h] == b'"' {
                let hashes = h - (i + 1);
                let mut j = h + 1;
                'raw: while j < b.len() {
                    if b[j] == b'"' {
                        let mut k = 0;
                        while k < hashes && j + 1 + k < b.len() && b[j + 1 + k] == b'#' {
                            k += 1;
                        }
                        if k == hashes {
                            j += 1 + hashes;
                            break 'raw;
                        }
                    }
                    if b[j] == b'\n' {
                        out.push(b'\n');
                    }
                    j += 1;
                }
                out.extend_from_slice(b"\"\"");
                i = j;
            } else {
                out.push(c);
                i += 1;
            }
        } else if c == b'"' {
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' {
                    i += 2;
                    continue;
                }
                if b[i] == b'"' {
                    i += 1;
                    break;
                }
                if b[i] == b'\n' {
                    out.push(b'\n');
                }
                i += 1;
            }
            out.extend_from_slice(b"\"\"");
        } else if c == b'\'' {
            if nxt == b'\\' {
                // Escaped char literal: consume the opening quote, the
                // backslash, and the escaped byte (so '\'' terminates on
                // the real closing quote), then scan to the close.
                i += 3;
                while i < b.len() && b[i] != b'\'' {
                    i += 1;
                }
                i += 1;
                out.extend_from_slice(b"' '");
            } else if i + 2 < b.len() && b[i + 2] == b'\'' && nxt != b'\'' {
                // Plain one-byte char literal 'x'.
                i += 3;
                out.extend_from_slice(b"' '");
            } else {
                // Lifetime.
                out.push(c);
                i += 1;
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Drop `#[cfg(test)]`-gated items by brace counting on the
/// comment/string-stripped text.
fn strip_cfg_test(text: &str) -> String {
    let lines: Vec<&str> = text.split('\n').collect();
    let mut keep: Vec<&str> = Vec::with_capacity(lines.len());
    let mut i = 0;
    while i < lines.len() {
        if lines[i].trim() == "#[cfg(test)]" {
            let mut depth: i64 = 0;
            let mut opened = false;
            let mut j = i;
            while j < lines.len() {
                for ch in lines[j].bytes() {
                    match ch {
                        b'{' => {
                            depth += 1;
                            opened = true;
                        }
                        b'}' => depth -= 1,
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                if !opened && j > i && lines[j].trim_end().ends_with(';') {
                    break; // `#[cfg(test)] mod x;` outline form
                }
                j += 1;
            }
            i = j + 1;
        } else {
            keep.push(lines[i]);
            i += 1;
        }
    }
    keep.join("\n")
}

fn count_substr(hay: &str, needle: &str) -> usize {
    hay.matches(needle).count()
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Count whole-identifier occurrences of `name`.
fn count_ident(hay: &str, name: &str) -> usize {
    let hb = hay.as_bytes();
    let mut n = 0;
    let mut from = 0;
    while let Some(pos) = hay
        .get(from..)
        .and_then(|s| s.find(name).map(|p| from + p))
    {
        let end = pos + name.len();
        let ok_left = pos == 0 || !is_ident(hb[pos - 1]);
        let ok_right = end >= hb.len() || !is_ident(hb[end]);
        if ok_left && ok_right {
            n += 1;
        }
        from = pos + 1;
    }
    n
}

/// Count dotted collective calls whose receiver identifier is not the
/// `metered_out` closure parameter `c`. Chained receivers (`foo().bar`)
/// have no receiver identifier and are not counted — direct calls are
/// what the seam rule polices.
fn seam_calls(text: &str) -> usize {
    let b = text.as_bytes();
    let mut count = 0;
    let mut i = 0;
    while i < b.len() {
        if b[i] != b'.' {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        while j < b.len() && b[j].is_ascii_whitespace() {
            j += 1;
        }
        let name_start = j;
        while j < b.len() && is_ident(b[j]) {
            j += 1;
        }
        if j > name_start {
            let name = &text[name_start..j];
            if COLLECTIVES.contains(&name) {
                let mut k = j;
                while k < b.len() && b[k].is_ascii_whitespace() {
                    k += 1;
                }
                if k < b.len() && b[k] == b'(' {
                    let mut r = i;
                    while r > 0 && b[r - 1].is_ascii_whitespace() {
                        r -= 1;
                    }
                    let recv_end = r;
                    while r > 0 && is_ident(b[r - 1]) {
                        r -= 1;
                    }
                    if recv_end > r && &text[r..recv_end] != "c" {
                        count += 1;
                    }
                }
            }
        }
        i = if j > i { j } else { i + 1 };
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripper_removes_comments_strings_chars() {
        let src = "let a = \".unwrap(\"; // .expect(\nlet b = '\\''; /* panic!( */ let c = 'x';";
        let t = strip_source(src);
        assert!(!t.contains(".unwrap("));
        assert!(!t.contains(".expect("));
        assert!(!t.contains("panic!("));
        assert_eq!(t.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn stripper_keeps_lifetimes_and_code() {
        let t = strip_source("fn f<'a>(x: &'a str) -> &'a str { x.trim() }");
        assert!(t.contains("<'a>"));
        assert!(t.contains("x.trim()"));
    }

    #[test]
    fn raw_strings_are_stripped() {
        let t = strip_source("let s = r#\"panic!( .unwrap( \"# ; let k = 1;");
        assert!(!t.contains("panic!("));
        assert!(t.contains("let k = 1;"));
    }

    #[test]
    fn cfg_test_blocks_are_dropped() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn lib2() {}";
        let t = strip_cfg_test(src);
        assert!(!t.contains("unwrap"));
        assert!(t.contains("lib2"));
    }

    #[test]
    fn ident_counting_respects_boundaries() {
        let t = "iallreduce_start iallreduce_start_extra x.iallreduce_start(";
        assert_eq!(count_ident(t, "iallreduce_start"), 2);
    }

    #[test]
    fn seam_calls_exempt_metered_closure_receiver() {
        let t = "c.allreduce_sum(&mut v); comm.allreduce_sum(&mut v); self.comm.barrier();";
        assert_eq!(seam_calls(t), 2);
    }
}
