//! The schedule checker: SPMD-safety invariants over per-rank
//! [`SpecEvent`] streams.
//!
//! [`check_streams`] proves four properties of an abstract schedule, each
//! a hard error when violated:
//!
//! * **(a) Lockstep** — all ranks issue identical op/tag/length
//!   sequences (deadlock-freedom of the SPMD schedule). Allreduce and
//!   broadcast payload lengths must match exactly; all-to-all *send*
//!   lengths are rank-dependent (Lemma-3 load imbalance), so the check
//!   is the transpose condition `send[r][q] == recv[q][r]` — every word
//!   rank r addresses to rank q is a word q's receive contract expects.
//! * **(b) Handle hygiene** — every `i*_start` is matched by exactly one
//!   wait before rank exit; no wait without a start.
//! * **(c) No tag aliasing** — while an operation is in flight, no other
//!   collective may carry its tag (tags are what keep in-flight message
//!   streams apart on the thread transport).
//! * **(d) Poison domination** — after a refused (poisoned) event,
//!   nothing but refused events may follow on any rank: a poisoned group
//!   must fail fast everywhere, never half-continue.
//!
//! Errors are [`Error::Comm`] with rank, stream position, and both sides
//! of the disagreement — enough to identify the offending `CaStep`
//! callback without rerunning anything.

use std::collections::VecDeque;

use crate::analysis::spec::{SpecEvent, SpecOp};
use crate::error::{Error, Result};

fn fail(msg: String) -> Result<()> {
    Err(Error::Comm(format!("schedule violation: {msg}")))
}

/// Verify invariants (a)–(d) over one stream per rank. `streams[r]` is
/// rank r's recorded sequence; an empty outer slice is an error (a
/// schedule with no ranks verifies nothing).
pub fn check_streams(streams: &[Vec<SpecEvent>]) -> Result<()> {
    if streams.is_empty() {
        return fail("no rank streams supplied".into());
    }
    let p = streams.len();

    // (a) lockstep: equal length, then position-wise agreement.
    let len0 = streams[0].len();
    for (r, st) in streams.iter().enumerate().skip(1) {
        if st.len() != len0 {
            let shorter = st.len().min(len0);
            let (lr, le) = if st.len() < len0 { (r, 0) } else { (0, r) };
            return fail(format!(
                "rank {lr} issued {} collectives but rank {le} issued {}; first \
                 missing position is {shorter} (rank {le} continues with `{}`)",
                streams[lr].len(),
                streams[le].len(),
                streams[le][shorter].token(),
            ));
        }
    }
    for pos in 0..len0 {
        let e0 = &streams[0][pos];
        for (r, st) in streams.iter().enumerate().skip(1) {
            let e = &st[pos];
            if e.tag != e0.tag || e.metered != e0.metered || e.op.class() != e0.op.class() {
                return fail(format!(
                    "rank divergence at position {pos}: rank 0 issued `{}` but rank \
                     {r} issued `{}` (op/tag/metered must match on every rank)",
                    e0.token(),
                    e.token(),
                ));
            }
            let lens_agree = match (&e0.op, &e.op) {
                (SpecOp::Allreduce { len: a }, SpecOp::Allreduce { len: b })
                | (SpecOp::IAllreduceStart { len: a }, SpecOp::IAllreduceStart { len: b })
                | (SpecOp::IAllreduceWait { len: a }, SpecOp::IAllreduceWait { len: b }) => a == b,
                (
                    SpecOp::Broadcast { root: ra, len: a },
                    SpecOp::Broadcast { root: rb, len: b },
                ) => ra == rb && a == b,
                // All-to-all payload agreement is the transpose condition,
                // checked across the whole group below.
                _ => true,
            };
            if !lens_agree {
                return fail(format!(
                    "payload divergence at position {pos}: rank 0 issued `{}` but \
                     rank {r} issued `{}`",
                    e0.token(),
                    e.token(),
                ));
            }
        }
        // (a) continued: all-to-all transpose condition over the group.
        let a2a = |op: &SpecOp| -> Option<(Vec<usize>, Vec<usize>)> {
            match op {
                SpecOp::AllToAll {
                    send_lens,
                    recv_lens,
                }
                | SpecOp::IAllToAllStart {
                    send_lens,
                    recv_lens,
                } => Some((send_lens.clone(), recv_lens.clone())),
                _ => None,
            }
        };
        if a2a(&e0.op).is_some() {
            let mut mats: Vec<(Vec<usize>, Vec<usize>)> = Vec::with_capacity(p);
            for (r, st) in streams.iter().enumerate() {
                match a2a(&st[pos].op) {
                    Some(m) => mats.push(m),
                    // Unreachable: op classes were matched above.
                    None => {
                        return fail(format!(
                            "internal: rank {r} op class changed at position {pos}"
                        ))
                    }
                }
            }
            for (r, (send, recv)) in mats.iter().enumerate() {
                if send.len() != p || recv.len() != p {
                    return fail(format!(
                        "all-to-all at position {pos}: rank {r} supplied {} send / \
                         {} receive lengths for a {p}-rank group",
                        send.len(),
                        recv.len(),
                    ));
                }
            }
            for r in 0..p {
                for q in 0..p {
                    if mats[r].0[q] != mats[q].1[r] {
                        return fail(format!(
                            "all-to-all length mismatch at position {pos} (tag {}): \
                             rank {r} sends {} words to rank {q}, but rank {q} \
                             expects {} words from rank {r}",
                            e0.tag, mats[r].0[q], mats[q].1[r],
                        ));
                    }
                }
            }
        }
    }

    // (b) + (c) + (d): per-rank in-flight simulation.
    for (r, st) in streams.iter().enumerate() {
        let mut flight_ar: VecDeque<u64> = VecDeque::new();
        let mut flight_a2a: VecDeque<u64> = VecDeque::new();
        let mut poisoned_at: Option<usize> = None;
        for (pos, e) in st.iter().enumerate() {
            // (d) nothing but refusals after a refusal.
            if let Some(first) = poisoned_at {
                if !matches!(e.op, SpecOp::Refused) {
                    return fail(format!(
                        "rank {r} issued `{}` at position {pos} after the group was \
                         poisoned at position {first}; a poisoned group must refuse \
                         every later collective",
                        e.token(),
                    ));
                }
                continue;
            }
            match &e.op {
                SpecOp::Refused => poisoned_at = Some(pos),
                SpecOp::IAllreduceWait { .. } => {
                    let Some(started) = flight_ar.pop_front() else {
                        return fail(format!(
                            "rank {r} waited on an allreduce at position {pos} (tag \
                             {}) with none in flight",
                            e.tag,
                        ));
                    };
                    if started != e.tag {
                        return fail(format!(
                            "rank {r} completed allreduce tag {} at position {pos} \
                             but the oldest in-flight allreduce is tag {started} \
                             (waits must complete in FIFO order)",
                            e.tag,
                        ));
                    }
                }
                SpecOp::IAllToAllWait { .. } => {
                    let Some(started) = flight_a2a.pop_front() else {
                        return fail(format!(
                            "rank {r} waited on an all-to-all at position {pos} (tag \
                             {}) with none in flight",
                            e.tag,
                        ));
                    };
                    if started != e.tag {
                        return fail(format!(
                            "rank {r} completed all-to-all tag {} at position {pos} \
                             but the oldest in-flight all-to-all is tag {started}",
                            e.tag,
                        ));
                    }
                }
                op => {
                    // (c) a new operation must not alias an in-flight tag.
                    if flight_ar.contains(&e.tag) || flight_a2a.contains(&e.tag) {
                        return fail(format!(
                            "tag aliasing on rank {r} at position {pos}: `{}` reuses \
                             tag {} while that tag is still in flight — its messages \
                             would be indistinguishable from the pending operation's",
                            e.token(),
                            e.tag,
                        ));
                    }
                    match op {
                        SpecOp::IAllreduceStart { .. } => flight_ar.push_back(e.tag),
                        SpecOp::IAllToAllStart { .. } => flight_a2a.push_back(e.tag),
                        _ => {}
                    }
                }
            }
        }
        // (b) every start matched by a wait before rank exit.
        if let Some(&tag) = flight_ar.front() {
            return fail(format!(
                "rank {r} exited with allreduce tag {tag} still in flight ({} \
                 orphaned allreduce start{}): every iallreduce_start needs exactly \
                 one iallreduce_wait",
                flight_ar.len(),
                if flight_ar.len() == 1 { "" } else { "s" },
            ));
        }
        if let Some(&tag) = flight_a2a.front() {
            return fail(format!(
                "rank {r} exited with all-to-all tag {tag} still in flight ({} \
                 orphaned all-to-all start{}): every iall_to_all_start needs \
                 exactly one iall_to_all_wait",
                flight_a2a.len(),
                if flight_a2a.len() == 1 { "" } else { "s" },
            ));
        }
    }

    Ok(())
}
