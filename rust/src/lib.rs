//! # cabcd — communication-avoiding block coordinate descent
//!
//! A distributed-memory reproduction of
//! *"Avoiding communication in primal and dual block coordinate descent
//! methods"* (Devarakonda, Fountoulakis, Demmel, Mahoney, 2016).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * [`engine`] — the unified s-step solver engine: the
//!   [`Problem`](engine::Problem)/[`Session`](engine::Session) API, the
//!   parsed [`Method`](engine::Method) selector, and the one pipeline
//!   core ([`engine::drive`]) that owns the outer loop and both
//!   execution schedules (blocking, and the overlapped prefetch
//!   pipeline) for every method below.
//! * [`solvers`] — Algorithms 1–4 of the paper (BCD, CA-BCD, BDCD, CA-BDCD)
//!   plus the CG and TSQR baselines of its §2.1 survey, all written against
//!   the [`comm`] communicator so they run SPMD over P simulated ranks —
//!   each as a small [`CaStep`](engine::CaStep) implementation.
//! * [`comm`] — an in-process MPI-like collectives substrate (binomial-tree
//!   allreduce / broadcast / all-to-all over channels) with per-rank α-β-γ
//!   cost meters.
//! * [`gram`] — the compute hot-spot (fused partial Gram + residual) with
//!   two interchangeable backends: a hand-optimized native path and the
//!   AOT-compiled JAX/Pallas artifact executed through [`runtime`] (PJRT).
//! * [`prox`] — the proximal regularization subsystem (L1 / elastic-net /
//!   none): separable prox operators, subgradient residuals, a
//!   primal/dual objective-gap certificate, and the CA-Prox-BCD/BDCD
//!   loops that reuse the packed `[G|r]` collective path verbatim.
//! * [`costmodel`] — the paper's analytic T = γF + αL + βW machine model
//!   (Theorems 1–9, Figures 8–9).
//! * [`telemetry`] — cross-rank runtime health: a zero-allocation
//!   metrics registry (counters / gauges / log2 histograms) aggregated
//!   on the record cadence into cluster snapshots with straggler
//!   detection, exported as Prometheus text and JSON.
//! * [`analysis`] — static SPMD safety: a symbolic schedule verifier
//!   (record every rank's abstract collective stream against a data-free
//!   [`SpecComm`](analysis::SpecComm), then prove lockstep / handle
//!   hygiene / tag uniqueness / poison domination) and the `ca_lint`
//!   token-level hygiene pass.
//! * [`matrix`], [`linalg`], [`partition`], [`sampling`] — the substrates:
//!   dense/CSR matrices, LIBSVM IO, dataset-clone generation, small SPD
//!   solves, TSQR, 1D layouts, shared-seed block sampling.
//!
//! Python/JAX appears **only at build time** (`make artifacts`); the binary
//! is self-contained once `artifacts/` exists.

pub mod analysis;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod engine;
pub mod error;
pub mod gram;
pub mod kernel;
pub mod linalg;
pub mod matrix;
pub mod metrics;
pub mod partition;
pub mod prox;
pub mod runtime;
pub mod sampling;
pub mod solvers;
pub mod telemetry;
pub mod trace;
pub mod util;

pub use error::{Error, Result};
