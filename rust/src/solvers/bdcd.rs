//! Dual block coordinate descent — Algorithm 3 (`s = 1`) and its
//! communication-avoiding unrolling, Algorithm 4 (`s > 1`).
//!
//! SPMD over a 1D-block-row partition of `X` — equivalently a 1D-block-
//! column partition of the dual operand `A = Xᵀ ∈ R^{n×d}`, which is how
//! this implementation views it. Each rank holds `A_loc = A[:, lo..hi]`
//! (all n data points, a feature slice), the matching slice `w_loc` of the
//! primal vector, and full replicas of the dual vector α and labels y.
//!
//! One outer iteration mirrors the primal exactly (same Gram engine, same
//! AOT artifacts): draw `s` size-`b'` blocks of `[n]`, compute the raw
//! partial `G = A_loc[J,:]·A_loc[J,:]ᵀ` (`= (XI)ᵀ(XI)` summed over ranks,
//! packed lower triangle — `sb(sb+1)/2 + sb` words on the wire) and
//! `r = A_loc[J,:]·w_loc` (`= IᵀXᵀw`), **one allreduce**, the s dual
//! subproblem solves of eq. (18), then the deferred updates
//! `α[J_t] += Δα_t` (replicated) and `w_loc -= (1/λn)·A_loc[J,:]ᵀ δ`.
//!
//! With [`SolverOpts::overlap`] the iteration is software-pipelined like
//! the primal solver: `G_{k+1}` (a function of A and the shared-seed
//! sample stream only) is computed while `[G_k | r_k]` reduces through the
//! non-blocking allreduce — one collective per outer iteration, bitwise
//! identical trajectory.

use crate::comm::Communicator;
use crate::error::Result;
use crate::gram::ComputeBackend;
use crate::linalg::packed::packed_len;
use crate::matrix::Matrix;
use crate::metrics::{
    relative_objective_error, relative_solution_error, History, IterRecord, Reference,
};
use crate::sampling::{overlap_tensor_into, BlockSampler};
use crate::solvers::common::{
    cond_stride, flatten_blocks, metered_out, objective_value, packed_gram_cond,
    should_record, DualOutput, SolverOpts,
};

/// Run BDCD / CA-BDCD on this rank's shard.
///
/// * `a_loc` — `n × d_loc` local column block of `A = Xᵀ`.
/// * `y` — full (replicated) label vector, length n.
/// * `d_global` — total feature dimension d (for `w_full` assembly).
/// * `d_offset` — global index of this rank's first feature column.
#[allow(clippy::too_many_arguments)]
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y: &[f64],
    d_global: usize,
    d_offset: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<DualOutput> {
    if !opts.reg.is_exact_l2() {
        // Non-smooth dual regularizer: the CA-Prox-BDCD loop.
        return crate::prox::bdcd::run(a_loc, y, d_global, d_offset, opts, comm, backend);
    }
    if opts.overlap {
        return run_overlapped(a_loc, y, d_global, d_offset, opts, reference, comm, backend);
    }
    let n = a_loc.rows();
    let d_loc = a_loc.cols();
    opts.validate(n)?;
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let inv_n = 1.0 / n as f64;
    let lam = opts.lam;

    // α₀ = 0 → w₀ = −(1/λn)·X·0 = 0.
    let mut alpha = vec![0.0; n];
    let mut w_loc = vec![0.0; d_loc];
    let mut history = History::default();

    let gl = packed_len(sb);
    let mut buf = vec![0.0; gl + sb]; // packed [G | r] allreduce payload
    let mut a_blocks = vec![0.0; sb];
    let mut y_blocks = vec![0.0; sb];
    let mut gram_scaled = vec![0.0; sb * sb];
    let mut idx_flat = vec![0usize; sb];
    let mut scaled_deltas = vec![0.0; sb];
    let mut overlap = vec![0.0; s * s * b * b];

    let mut sampler = BlockSampler::new(n, opts.seed);

    record(
        &mut history,
        0,
        &w_loc,
        d_global,
        d_offset,
        a_loc,
        y,
        lam,
        reference,
        comm,
    )?;

    let outer = opts.outer_iters();
    let stride = cond_stride(sb, outer);
    'outer_loop: for k in 0..outer {
        let blocks = sampler.draw_blocks(s, b);
        flatten_blocks(&blocks, b, &mut idx_flat);

        // Raw partial Gram + residual (contracting along the local feature
        // slice): G_part = A[J,:]·A[J,:]ᵀ (packed), r_part = A[J,:]·w_loc.
        let (g_buf, r_buf) = buf.split_at_mut(gl);
        backend.gram_resid(a_loc, &idx_flat, &w_loc, g_buf, r_buf)?;

        // THE communication of this outer iteration.
        comm.allreduce_sum(&mut buf)?;

        if opts.track_gram_cond && k % stride == 0 {
            // Θ-scale Gram: G' = (1/λn²)·raw + (1/n)I (paper Figs. 7i–l).
            history.gram_conds.push(packed_gram_cond(
                &buf,
                sb,
                inv_n * inv_n / lam,
                inv_n,
                &mut gram_scaled,
            ));
        }

        // Replicated dual inner solve (eq. 18).
        overlap_tensor_into(&blocks, &mut overlap);
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                a_blocks[j * b + i] = alpha[row];
                y_blocks[j * b + i] = y[row];
            }
        }
        let (g_buf, r_buf) = buf.split_at(gl);
        let deltas = backend.ca_dual_inner_solve(
            s, b, g_buf, r_buf, &a_blocks, &y_blocks, &overlap, lam, inv_n,
        )?;

        // Deferred updates (eqs. 19–20).
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                alpha[row] += deltas[j * b + i];
            }
        }
        let scale = -1.0 / (lam * n as f64);
        for (sd, &dv) in scaled_deltas.iter_mut().zip(&deltas) {
            *sd = scale * dv;
        }
        backend.alpha_update(a_loc, &idx_flat, &scaled_deltas, &mut w_loc)?;

        let h_now = (k + 1) * s;
        history.iters = h_now;
        if should_record(h_now, s, opts) || k + 1 == outer {
            record(
                &mut history,
                h_now,
                &w_loc,
                d_global,
                d_offset,
                a_loc,
                y,
                lam,
                reference,
                comm,
            )?;
            if let (Some(tol), Some(_)) = (opts.tol, reference) {
                if history.final_obj_err() <= tol {
                    break 'outer_loop;
                }
            }
        }
    }

    history.meter = *comm.meter();
    let w_full = gather_w(&w_loc, d_global, d_offset, comm)?;
    Ok(DualOutput {
        w_loc,
        w_full,
        alpha,
        history,
    })
}

/// Software-pipelined variant (`opts.overlap`): `[G_k | r_k]` reduces
/// non-blockingly while `G_{k+1}` and the overlap tensor are computed.
/// One collective per outer iteration, bitwise identical to blocking.
#[allow(clippy::too_many_arguments)]
fn run_overlapped<C: Communicator>(
    a_loc: &Matrix,
    y: &[f64],
    d_global: usize,
    d_offset: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<DualOutput> {
    let n = a_loc.rows();
    let d_loc = a_loc.cols();
    opts.validate(n)?;
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let gl = packed_len(sb);
    let inv_n = 1.0 / n as f64;
    let lam = opts.lam;

    let mut alpha = vec![0.0; n];
    let mut w_loc = vec![0.0; d_loc];
    let mut history = History::default();

    let mut a_blocks = vec![0.0; sb];
    let mut y_blocks = vec![0.0; sb];
    let mut gram_scaled = vec![0.0; sb * sb];
    let mut idx_cur = vec![0usize; sb];
    let mut idx_next = vec![0usize; sb];
    let mut scaled_deltas = vec![0.0; sb];
    let mut overlap = vec![0.0; s * s * b * b];

    let mut sampler = BlockSampler::new(n, opts.seed);

    record(
        &mut history,
        0,
        &w_loc,
        d_global,
        d_offset,
        a_loc,
        y,
        lam,
        reference,
        comm,
    )?;

    let outer = opts.outer_iters();
    let stride = cond_stride(sb, outer);

    let mut blocks: Vec<Vec<usize>> = Vec::new();
    let mut next_buf: Vec<f64> = Vec::new();
    if outer > 0 {
        blocks = sampler.draw_blocks(s, b);
        flatten_blocks(&blocks, b, &mut idx_cur);
        next_buf = comm.take_buf(gl + sb);
        backend.gram_only(a_loc, &idx_cur, &mut next_buf[..gl])?;
    }
    'outer_loop: for k in 0..outer {
        let mut buf = std::mem::take(&mut next_buf); // holds G_k (packed)

        // r_k = A_loc[J,:] · w_loc into the buffer tail.
        backend.resid_only(a_loc, &idx_cur, &w_loc, &mut buf[gl..])?;

        // THE communication of this outer iteration — non-blocking.
        let handle = comm.iallreduce_start(buf)?;

        // ---- local work hidden behind the in-flight reduction -----------
        let mut pending_blocks: Option<Vec<Vec<usize>>> = None;
        if k + 1 < outer {
            let nb = sampler.draw_blocks(s, b);
            flatten_blocks(&nb, b, &mut idx_next);
            next_buf = comm.take_buf(gl + sb);
            backend.gram_only(a_loc, &idx_next, &mut next_buf[..gl])?;
            pending_blocks = Some(nb);
        }
        overlap_tensor_into(&blocks, &mut overlap);
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                a_blocks[j * b + i] = alpha[row];
                y_blocks[j * b + i] = y[row];
            }
        }
        // ------------------------------------------------------------------
        let buf = comm.iallreduce_wait(handle)?;

        if opts.track_gram_cond && k % stride == 0 {
            history.gram_conds.push(packed_gram_cond(
                &buf,
                sb,
                inv_n * inv_n / lam,
                inv_n,
                &mut gram_scaled,
            ));
        }

        // Replicated dual inner solve (eq. 18) and deferred updates.
        let (g_buf, r_buf) = buf.split_at(gl);
        let deltas = backend.ca_dual_inner_solve(
            s, b, g_buf, r_buf, &a_blocks, &y_blocks, &overlap, lam, inv_n,
        )?;
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                alpha[row] += deltas[j * b + i];
            }
        }
        let scale = -1.0 / (lam * n as f64);
        for (sd, &dv) in scaled_deltas.iter_mut().zip(&deltas) {
            *sd = scale * dv;
        }
        backend.alpha_update(a_loc, &idx_cur, &scaled_deltas, &mut w_loc)?;
        comm.give_buf(buf);

        if let Some(nb) = pending_blocks {
            blocks = nb;
            std::mem::swap(&mut idx_cur, &mut idx_next);
        }

        let h_now = (k + 1) * s;
        history.iters = h_now;
        if should_record(h_now, s, opts) || k + 1 == outer {
            record(
                &mut history,
                h_now,
                &w_loc,
                d_global,
                d_offset,
                a_loc,
                y,
                lam,
                reference,
                comm,
            )?;
            if let (Some(tol), Some(_)) = (opts.tol, reference) {
                if history.final_obj_err() <= tol {
                    break 'outer_loop;
                }
            }
        }
    }
    if !next_buf.is_empty() {
        comm.give_buf(next_buf);
    }

    history.meter = *comm.meter();
    let w_full = gather_w(&w_loc, d_global, d_offset, comm)?;
    Ok(DualOutput {
        w_loc,
        w_full,
        alpha,
        history,
    })
}

/// Assemble the full w by summing zero-padded local slices (metric path).
fn gather_w<C: Communicator>(
    w_loc: &[f64],
    d_global: usize,
    d_offset: usize,
    comm: &mut C,
) -> Result<Vec<f64>> {
    metered_out(comm, |c| {
        let mut full = vec![0.0; d_global];
        full[d_offset..d_offset + w_loc.len()].copy_from_slice(w_loc);
        c.allreduce_sum(&mut full)?;
        Ok(full)
    })
}

/// Metric evaluation for the dual solver. The primal objective needs the
/// full `Xᵀw = A·w`: each rank contributes `A_loc·w_loc`, one n-vector
/// allreduce (meter-excluded), then the objective and errors follow.
#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w_loc: &[f64],
    _d_global: usize,
    d_offset: usize,
    a_loc: &Matrix,
    y: &[f64],
    lam: f64,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<()> {
    let Some(r) = reference else { return Ok(()) };
    let n = a_loc.rows();
    let (xtw, w_norm_sq, sol_err_sq) = metered_out(comm, |c| {
        // payload = [A_loc·w_loc (n) | ‖w_loc‖² | ‖w_loc − w_opt_loc‖²]
        let mut payload = vec![0.0; n + 2];
        let (head, tail) = payload.split_at_mut(n);
        a_loc.matvec(w_loc, head)?;
        tail[0] = w_loc.iter().map(|v| v * v).sum();
        tail[1] = w_loc
            .iter()
            .zip(&r.w_opt[d_offset..d_offset + w_loc.len()])
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        c.allreduce_sum(&mut payload)?;
        let wns = payload[n];
        let ses = payload[n + 1];
        payload.truncate(n);
        Ok((payload, wns, ses))
    })?;
    let resid_sq: f64 = xtw.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
    let f_alg = objective_value(resid_sq, w_norm_sq, n, lam);
    let w_opt_norm_sq: f64 = r.w_opt.iter().map(|v| v * v).sum();
    history.records.push(IterRecord {
        iter,
        obj_err: relative_objective_error(f_alg, r.f_opt),
        sol_err: (sol_err_sq / w_opt_norm_sq.max(1e-300)).sqrt(),
    });
    let _ = relative_solution_error; // (primal-path helper; dual computes distributed)
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::matrix::{DenseMatrix, Matrix};

    fn toy() -> (Matrix, Vec<f64>) {
        // X: 5 features × 30 points → A = Xᵀ is 30 × 5.
        let mut data = vec![0.0; 5 * 30];
        let mut state = 123u64;
        for v in data.iter_mut() {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = (state as f64 / u64::MAX as f64) - 0.5;
        }
        let x = DenseMatrix::from_vec(5, 30, data);
        let xm = Matrix::Dense(x);
        let mut y = vec![0.0; 30];
        xm.matvec_t(&[0.5; 5], &mut y).unwrap();
        (xm, y)
    }

    fn solve_direct(x: &Matrix, y: &[f64], lam: f64) -> Vec<f64> {
        let d = x.rows();
        let n = x.cols();
        let idx: Vec<usize> = (0..d).collect();
        let mut g = vec![0.0; d * d];
        x.sampled_gram(&idx, &mut g).unwrap();
        for i in 0..d {
            for j in 0..d {
                g[i * d + j] /= n as f64;
            }
            g[i * d + i] += lam;
        }
        let mut rhs = vec![0.0; d];
        x.matvec(y, &mut rhs).unwrap();
        for v in rhs.iter_mut() {
            *v /= n as f64;
        }
        crate::linalg::chol_solve(&g, d, &mut rhs).unwrap();
        rhs
    }

    #[test]
    fn bdcd_converges_to_primal_ridge_solution() {
        let (x, y) = toy();
        let lam = 0.1;
        let w_opt = solve_direct(&x, &y, lam);
        let a = x.transpose(); // 30 × 5
        let opts = SolverOpts {
            b: 4,
            s: 1,
            lam,
            iters: 6000,
            seed: 2,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let out = run(&a, &y, 5, 0, &opts, None, &mut comm, &mut be).unwrap();
        let err = relative_solution_error(&out.w_full, &w_opt);
        assert!(err < 1e-6, "solution error {err}");
    }

    #[test]
    fn ca_bdcd_matches_bdcd_trajectory() {
        let (x, y) = toy();
        let a = x.transpose();
        let lam = 0.1;
        let mk = |s: usize| SolverOpts {
            b: 3,
            s,
            lam,
            iters: 40,
            seed: 11,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&a, &y, 5, 0, &mk(1), None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        let w2 = run(&a, &y, 5, 0, &mk(4), None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        for (p, q) in w1.iter().zip(&w2) {
            assert!((p - q).abs() < 1e-10, "{p} vs {q}");
        }
    }

    #[test]
    fn overlap_mode_is_bitwise_identical_serial() {
        let (x, y) = toy();
        let a = x.transpose();
        let mut opts = SolverOpts {
            b: 3,
            s: 4,
            lam: 0.1,
            iters: 24,
            seed: 6,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&a, &y, 5, 0, &opts, None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        opts.overlap = true;
        let w2 = run(&a, &y, 5, 0, &opts, None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        assert_eq!(w1, w2, "overlap pipeline changed the dual trajectory");
    }

    #[test]
    fn dual_coupling_invariant_holds() {
        // w = −(1/λn)·X·α must hold at every outer boundary; check at end.
        let (x, y) = toy();
        let a = x.transpose();
        let lam = 0.1;
        let opts = SolverOpts {
            b: 5,
            s: 2,
            lam,
            iters: 30,
            seed: 4,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let out = run(&a, &y, 5, 0, &opts, None, &mut comm, &mut be).unwrap();
        let n = 30.0;
        let mut w_expect = vec![0.0; 5];
        x.matvec(&out.alpha, &mut w_expect).unwrap();
        for v in w_expect.iter_mut() {
            *v *= -1.0 / (lam * n);
        }
        for (p, q) in out.w_full.iter().zip(&w_expect) {
            assert!((p - q).abs() < 1e-10, "{p} vs {q}");
        }
    }
}
