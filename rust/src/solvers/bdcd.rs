//! Dual block coordinate descent — Algorithm 3 (`s = 1`) and its
//! communication-avoiding unrolling, Algorithm 4 (`s > 1`).
//!
//! SPMD over a 1D-block-row partition of `X` — equivalently a 1D-block-
//! column partition of the dual operand `A = Xᵀ ∈ R^{n×d}`, which is how
//! this implementation views it. Each rank holds `A_loc = A[:, lo..hi]`
//! (all n data points, a feature slice), the matching slice `w_loc` of the
//! primal vector, and full replicas of the dual vector α and labels y.
//!
//! One outer iteration mirrors the primal exactly (same Gram engine, same
//! AOT artifacts): draw `s` size-`b'` blocks of `[n]`, compute the raw
//! partial `G = A_loc[J,:]·A_loc[J,:]ᵀ` (`= (XI)ᵀ(XI)` summed over ranks,
//! packed lower triangle — `sb(sb+1)/2 + sb` words on the wire) and
//! `r = A_loc[J,:]·w_loc` (`= IᵀXᵀw`), **one allreduce**, the s dual
//! subproblem solves of eq. (18), then the deferred updates
//! `α[J_t] += Δα_t` (replicated) and `w_loc -= (1/λn)·A_loc[J,:]ᵀ δ`.
//!
//! The loop lives in the shared pipeline core ([`crate::engine::drive`]);
//! this module contributes the method callbacks ([`BdcdStep`]). With
//! [`SolverOpts::overlap`] the engine's prefetch schedule computes
//! `G_{k+1}` (a function of A and the shared-seed sample stream only)
//! while `[G_k | r_k]` reduces through the non-blocking allreduce — one
//! collective per outer iteration, bitwise identical trajectory.

use crate::comm::Communicator;
use crate::engine::{drive, CaStep, Checkpoint, Method, Problem, Sample, Session};
use crate::error::Result;
use crate::gram::ComputeBackend;
use crate::linalg::packed::packed_len;
use crate::matrix::Matrix;
use crate::metrics::{
    relative_objective_error, relative_solution_error, History, IterRecord, Reference,
};
use crate::sampling::{overlap_tensor_into, BlockSampler};
use crate::solvers::common::{metered_out, objective_value, DualOutput, SolverOpts};

/// Run BDCD / CA-BDCD on this rank's shard.
///
/// Thin wrapper over the engine's single entry point (see
/// [`crate::engine::Session`]); non-L2 regularizers route through the
/// CA-Prox-BDCD loop.
///
/// * `a_loc` — `n × d_loc` local column block of `A = Xᵀ`.
/// * `y` — full (replicated) label vector, length n.
/// * `d_global` — total feature dimension d (for `w_full` assembly).
/// * `d_offset` — global index of this rank's first feature column.
#[allow(clippy::too_many_arguments)]
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y: &[f64],
    d_global: usize,
    d_offset: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<DualOutput> {
    let problem = Problem::dual(a_loc, y, d_global, d_offset).with_reference(reference);
    Session::new(&problem)
        .opts(opts.clone())
        .method(Method::CaBdcd)
        .backend(backend)
        .comm(comm)
        .run()?
        .into_dual()
}

/// Engine entry point: build the [`BdcdStep`], drive it, gather `w_full`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn engine_run<C: Communicator>(
    a_loc: &Matrix,
    y: &[f64],
    d_global: usize,
    d_offset: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<DualOutput> {
    let n = a_loc.rows();
    let d_loc = a_loc.cols();
    opts.validate(n)?;
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let mut history = History::default();
    let mut step = BdcdStep {
        a_loc,
        y,
        d_offset,
        reference,
        backend,
        s,
        b,
        lam: opts.lam,
        inv_n: 1.0 / n as f64,
        w_scale: -1.0 / (opts.lam * n as f64),
        gl: packed_len(sb),
        sampler: BlockSampler::new(n, opts.seed),
        // α₀ = 0 → w₀ = −(1/λn)·X·0 = 0.
        alpha: vec![0.0; n],
        w_loc: vec![0.0; d_loc],
        a_blocks: vec![0.0; sb],
        y_blocks: vec![0.0; sb],
        scaled_deltas: vec![0.0; sb],
        overlap: vec![0.0; s * s * b * b],
    };
    drive(&mut step, opts, comm, &mut history)?;
    let w_full = gather_w(&step.w_loc, d_global, d_offset, comm)?;
    Ok(DualOutput {
        w_loc: step.w_loc,
        w_full,
        alpha: step.alpha,
        history,
    })
}

/// The matched-layout dual method's per-iteration callbacks.
pub(crate) struct BdcdStep<'a> {
    a_loc: &'a Matrix,
    y: &'a [f64],
    d_offset: usize,
    reference: Option<&'a Reference>,
    backend: &'a mut dyn ComputeBackend,
    s: usize,
    b: usize,
    lam: f64,
    inv_n: f64,
    /// `−1/(λn)`, the deferred w-update scale of eq. (20) — precomputed
    /// with the exact expression the classical loop used so the
    /// trajectory stays bitwise identical.
    w_scale: f64,
    gl: usize,
    sampler: BlockSampler,
    /// Replicated dual iterate.
    alpha: Vec<f64>,
    /// This rank's slice of w = −(1/λn)·Xα.
    w_loc: Vec<f64>,
    a_blocks: Vec<f64>,
    y_blocks: Vec<f64>,
    scaled_deltas: Vec<f64>,
    overlap: Vec<f64>,
}

impl<C: Communicator> CaStep<C> for BdcdStep<'_> {
    fn payload_split(&self) -> (usize, usize) {
        (self.gl, self.s * self.b)
    }

    fn prefetch_gram(&self) -> bool {
        true
    }

    fn sample(&mut self, _comm: &mut C, k: usize) -> Result<Sample> {
        Ok(Sample::flatten(
            k,
            self.sampler.draw_blocks(self.s, self.b),
            self.b,
        ))
    }

    fn local_gram(&mut self, _comm: &mut C, smp: &Sample, head: &mut [f64]) -> Result<()> {
        // Raw partial Gram (contracting along the local feature slice):
        // G_part = A[J,:]·A[J,:]ᵀ (packed).
        self.backend.gram_only(self.a_loc, &smp.idx, head)
    }

    fn local_state(&mut self, smp: &Sample, tail: &mut [f64]) -> Result<()> {
        // r_part = A[J,:]·w_loc into the payload tail.
        self.backend
            .resid_only(self.a_loc, &smp.idx, &self.w_loc, tail)
    }

    fn local_payload(
        &mut self,
        _comm: &mut C,
        smp: &Sample,
        head: &mut [f64],
        tail: &mut [f64],
    ) -> Result<()> {
        // Same-iteration gram + residual: one fused backend call, like
        // the pre-engine blocking loop.
        self.backend
            .gram_resid(self.a_loc, &smp.idx, &self.w_loc, head, tail)
    }

    fn hidden_work(&mut self, smp: &Sample) -> Result<()> {
        overlap_tensor_into(&smp.blocks, &mut self.overlap);
        for (j, blk) in smp.blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                self.a_blocks[j * self.b + i] = self.alpha[row];
                self.y_blocks[j * self.b + i] = self.y[row];
            }
        }
        Ok(())
    }

    fn cond_probe(&self) -> Option<(f64, f64)> {
        // Θ-scale Gram: G' = (1/λn²)·raw + (1/n)I (paper Figs. 7i–l).
        Some((self.inv_n * self.inv_n / self.lam, self.inv_n))
    }

    fn inner_solve(&mut self, _smp: &Sample, head: &[f64], tail: &[f64]) -> Result<Vec<f64>> {
        // Replicated dual inner solve (eq. 18).
        self.backend.ca_dual_inner_solve(
            self.s,
            self.b,
            head,
            tail,
            &self.a_blocks,
            &self.y_blocks,
            &self.overlap,
            self.lam,
            self.inv_n,
        )
    }

    fn apply(&mut self, smp: &Sample, deltas: &[f64]) -> Result<()> {
        // Deferred updates (eqs. 19–20).
        for (j, blk) in smp.blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                self.alpha[row] += deltas[j * self.b + i];
            }
        }
        for (sd, &dv) in self.scaled_deltas.iter_mut().zip(deltas) {
            *sd = self.w_scale * dv;
        }
        self.backend
            .alpha_update(self.a_loc, &smp.idx, &self.scaled_deltas, &mut self.w_loc)
    }

    fn record(&mut self, comm: &mut C, history: &mut History, h_now: usize) -> Result<()> {
        record(
            history,
            h_now,
            &self.w_loc,
            self.d_offset,
            self.a_loc,
            self.y,
            self.lam,
            self.reference,
            comm,
        )
    }

    fn converged(&self, history: &History, tol: f64) -> bool {
        self.reference.is_some() && history.final_obj_err() <= tol
    }

    fn ckpt_kind(&self) -> &'static str {
        "bdcd"
    }

    fn save_state(&self, ckpt: &mut Checkpoint) -> Result<()> {
        // Full mutable state: sampler RNG + the dual iterate + this
        // rank's w slice. a_blocks / y_blocks / scaled_deltas / overlap
        // are scratch, refilled before every use.
        ckpt.rng = self.sampler.rng_state().to_vec();
        ckpt.push_f64("alpha", &self.alpha);
        ckpt.push_f64("w_loc", &self.w_loc);
        Ok(())
    }

    fn restore_state(&mut self, ckpt: &Checkpoint) -> Result<()> {
        self.sampler.set_rng_state(ckpt.rng_words()?);
        ckpt.read_f64_into("alpha", &mut self.alpha)?;
        ckpt.read_f64_into("w_loc", &mut self.w_loc)
    }
}

/// Assemble the full w by summing zero-padded local slices (metric path).
fn gather_w<C: Communicator>(
    w_loc: &[f64],
    d_global: usize,
    d_offset: usize,
    comm: &mut C,
) -> Result<Vec<f64>> {
    metered_out(comm, |c| {
        let mut full = vec![0.0; d_global];
        full[d_offset..d_offset + w_loc.len()].copy_from_slice(w_loc);
        c.allreduce_sum(&mut full)?;
        Ok(full)
    })
}

/// Metric evaluation for the dual solver. The primal objective needs the
/// full `Xᵀw = A·w`: each rank contributes `A_loc·w_loc`, one n-vector
/// allreduce (meter-excluded), then the objective and errors follow.
#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w_loc: &[f64],
    d_offset: usize,
    a_loc: &Matrix,
    y: &[f64],
    lam: f64,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<()> {
    let Some(r) = reference else { return Ok(()) };
    let n = a_loc.rows();
    let (xtw, w_norm_sq, sol_err_sq) = metered_out(comm, |c| {
        // payload = [A_loc·w_loc (n) | ‖w_loc‖² | ‖w_loc − w_opt_loc‖²]
        let mut payload = vec![0.0; n + 2];
        let (head, tail) = payload.split_at_mut(n);
        a_loc.matvec(w_loc, head)?;
        tail[0] = w_loc.iter().map(|v| v * v).sum();
        tail[1] = w_loc
            .iter()
            .zip(&r.w_opt[d_offset..d_offset + w_loc.len()])
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        c.allreduce_sum(&mut payload)?;
        let wns = payload[n];
        let ses = payload[n + 1];
        payload.truncate(n);
        Ok((payload, wns, ses))
    })?;
    let resid_sq: f64 = xtw.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
    let f_alg = objective_value(resid_sq, w_norm_sq, n, lam);
    let w_opt_norm_sq: f64 = r.w_opt.iter().map(|v| v * v).sum();
    history.records.push(IterRecord {
        iter,
        obj_err: relative_objective_error(f_alg, r.f_opt),
        sol_err: (sol_err_sq / w_opt_norm_sq.max(1e-300)).sqrt(),
    });
    let _ = relative_solution_error; // (primal-path helper; dual computes distributed)
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::matrix::{DenseMatrix, Matrix};

    fn toy() -> (Matrix, Vec<f64>) {
        // X: 5 features × 30 points → A = Xᵀ is 30 × 5.
        let mut data = vec![0.0; 5 * 30];
        let mut state = 123u64;
        for v in data.iter_mut() {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = (state as f64 / u64::MAX as f64) - 0.5;
        }
        let x = DenseMatrix::from_vec(5, 30, data);
        let xm = Matrix::Dense(x);
        let mut y = vec![0.0; 30];
        xm.matvec_t(&[0.5; 5], &mut y).unwrap();
        (xm, y)
    }

    fn solve_direct(x: &Matrix, y: &[f64], lam: f64) -> Vec<f64> {
        let d = x.rows();
        let n = x.cols();
        let idx: Vec<usize> = (0..d).collect();
        let mut g = vec![0.0; d * d];
        x.sampled_gram(&idx, &mut g).unwrap();
        for i in 0..d {
            for j in 0..d {
                g[i * d + j] /= n as f64;
            }
            g[i * d + i] += lam;
        }
        let mut rhs = vec![0.0; d];
        x.matvec(y, &mut rhs).unwrap();
        for v in rhs.iter_mut() {
            *v /= n as f64;
        }
        crate::linalg::chol_solve(&g, d, &mut rhs).unwrap();
        rhs
    }

    #[test]
    fn bdcd_converges_to_primal_ridge_solution() {
        let (x, y) = toy();
        let lam = 0.1;
        let w_opt = solve_direct(&x, &y, lam);
        let a = x.transpose(); // 30 × 5
        let opts = SolverOpts {
            b: 4,
            s: 1,
            lam,
            iters: 6000,
            seed: 2,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let out = run(&a, &y, 5, 0, &opts, None, &mut comm, &mut be).unwrap();
        let err = relative_solution_error(&out.w_full, &w_opt);
        assert!(err < 1e-6, "solution error {err}");
    }

    #[test]
    fn ca_bdcd_matches_bdcd_trajectory() {
        let (x, y) = toy();
        let a = x.transpose();
        let lam = 0.1;
        let mk = |s: usize| SolverOpts {
            b: 3,
            s,
            lam,
            iters: 40,
            seed: 11,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&a, &y, 5, 0, &mk(1), None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        let w2 = run(&a, &y, 5, 0, &mk(4), None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        for (p, q) in w1.iter().zip(&w2) {
            assert!((p - q).abs() < 1e-10, "{p} vs {q}");
        }
    }

    #[test]
    fn overlap_mode_is_bitwise_identical_serial() {
        let (x, y) = toy();
        let a = x.transpose();
        let mut opts = SolverOpts {
            b: 3,
            s: 4,
            lam: 0.1,
            iters: 24,
            seed: 6,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&a, &y, 5, 0, &opts, None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        opts.overlap = true;
        let w2 = run(&a, &y, 5, 0, &opts, None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        assert_eq!(w1, w2, "overlap pipeline changed the dual trajectory");
    }

    #[test]
    fn dual_coupling_invariant_holds() {
        // w = −(1/λn)·X·α must hold at every outer boundary; check at end.
        let (x, y) = toy();
        let a = x.transpose();
        let lam = 0.1;
        let opts = SolverOpts {
            b: 5,
            s: 2,
            lam,
            iters: 30,
            seed: 4,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let out = run(&a, &y, 5, 0, &opts, None, &mut comm, &mut be).unwrap();
        let n = 30.0;
        let mut w_expect = vec![0.0; 5];
        x.matvec(&out.alpha, &mut w_expect).unwrap();
        for v in w_expect.iter_mut() {
            *v *= -1.0 / (lam * n);
        }
        for (p, q) in out.w_full.iter().zip(&w_expect) {
            assert!((p - q).abs() < 1e-10, "{p} vs {q}");
        }
    }
}
