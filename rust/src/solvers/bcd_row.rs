//! BCD / CA-BCD under the **mismatched** 1D-block-row layout
//! (Theorems 4 and 8): X's rows (features) are partitioned, so the sampled
//! `sb × n` block is scattered across owners and must be converted to the
//! 1D-block-column layout by an **all-to-all** before every Gram
//! computation — the paper's load-balancing redistribution, whose volume is
//! bounded by the Lemma-3 balls-into-bins maximum load.
//!
//! Layout duals of the matched case: vectors in `R^d` (w) are partitioned,
//! vectors in `R^n` (y, α) are partitioned too (each rank owns a column
//! range); the inner solve still runs replicated, fed by the allreduce.
//! The trajectory is **identical** to the block-column solver under the
//! same seed — asserted by the layout-equivalence integration test — only
//! the communication pattern differs (extra all-to-all per outer
//! iteration, exactly Theorem 8's `W` term).
//!
//! The loop lives in the shared pipeline core ([`crate::engine::drive`]);
//! this module contributes the method callbacks ([`BcdRowStep`]). With
//! [`SolverOpts::overlap`], the step runs a one-iteration **all-to-all
//! look-ahead** through the engine's prefetch hooks: iteration `k+1`'s
//! Theorem-4 exchange is posted (`iall_to_all_start`) as soon as
//! iteration `k`'s receives have drained, so its payloads are in flight
//! while this rank computes `G_k` — the Y_cols reassembly no longer waits
//! on cold receives — and the Lemma-3 load-metering allreduce rides inside
//! the in-flight exchange (operation tags keep the streams apart). The
//! reassembled panel, the Gram compute, and the overlap-tensor assembly
//! all additionally hide under the in-flight `[G|r|w]` reduction.
//! Payloads and per-source ordering are unchanged, so trajectories and
//! measured loads are **bitwise identical** to the blocking path.
//!
//! The look-ahead engages only for fixed-length runs
//! ([`SolverOpts::tol`] unset): a mid-run tolerance stop would cancel an
//! exchange whose messages are already on the wire, so with a tolerance
//! configured the overlap path falls back to the per-iteration
//! non-blocking exchange (the pre-engine overlap schedule — load
//! metering still hides inside the in-flight a2a, the tensor under the
//! `[G|r|w]` reduction), keeping early-stop wire counts and measured
//! loads exactly equal to the blocking path.

use crate::comm::{AllToAllHandle, Communicator};
use crate::engine::{checkpoint, drive, CaStep, Checkpoint, Method, Problem, Sample, Session};
use crate::error::{Error, Result};
use crate::gram::ComputeBackend;
use crate::linalg::packed::packed_len;
use crate::matrix::{DenseMatrix, Matrix};
use crate::metrics::{
    relative_objective_error, relative_solution_error, History, IterRecord, Reference,
};
use crate::partition::BlockPartition;
use crate::sampling::{overlap_tensor_into, BlockSampler};
use crate::solvers::common::{metered_out, objective_value, SolverOpts};

/// Output of the row-layout primal solver.
#[derive(Clone, Debug)]
pub struct RowPrimalOutput {
    /// This rank's slice of w (feature range `d_range`).
    pub w_loc: Vec<f64>,
    /// Full w (assembled once at the end, metric path).
    pub w_full: Vec<f64>,
    /// Trajectory + communication accounting of the run.
    pub history: History,
    /// Max sampled rows owned by any single rank, per outer iteration —
    /// the measured Lemma-3 load (tested against O(ln b / ln ln b)).
    pub max_loads: Vec<usize>,
}

/// Run BCD / CA-BCD with X stored 1D-block-row.
///
/// Thin wrapper over the engine's single entry point (see
/// [`crate::engine::Session`]). Supports `reg = l2` only; prox
/// regularizers run through the matched layouts.
///
/// * `x_rows` — this rank's `d_loc × n` slab of X (full rows).
/// * `y_loc` — this rank's slice of y for the column range it owns
///   (column ranges are the canonical `BlockPartition::new(n, P)`).
/// * `d_global`, `d_offset` — feature partition bookkeeping.
#[allow(clippy::too_many_arguments)]
pub fn run<C: Communicator>(
    x_rows: &Matrix,
    y_loc: &[f64],
    d_global: usize,
    d_offset: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<RowPrimalOutput> {
    let problem = Problem::primal_rows(x_rows, y_loc, d_global, d_offset).with_reference(reference);
    Session::new(&problem)
        .opts(opts.clone())
        .method(Method::CaBcdRow)
        .backend(backend)
        .comm(comm)
        .run()?
        .into_row_primal()
}

/// Engine entry point: build the [`BcdRowStep`], drive it, gather `w_full`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn engine_run<C: Communicator>(
    x_rows: &Matrix,
    y_loc: &[f64],
    d_global: usize,
    d_offset: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<RowPrimalOutput> {
    if !opts.reg.is_exact_l2() {
        return Err(Error::InvalidArg(
            "bcd_row supports reg = l2 only; prox regularizers run through \
             solvers::bcd / solvers::bdcd (matched layouts)"
                .into(),
        ));
    }
    let d_loc = x_rows.rows();
    let n = x_rows.cols();
    opts.validate(d_global)?;
    let p = comm.size();
    let rank = comm.rank();
    let row_part = BlockPartition::new(d_global, p);
    let col_part = BlockPartition::new(n, p);
    let (col_lo, col_hi) = col_part.range(rank);
    let n_loc = col_hi - col_lo;
    if y_loc.len() != n_loc {
        return Err(Error::Shape(format!(
            "row-layout: y_loc {} != column range {}",
            y_loc.len(),
            n_loc
        )));
    }
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let mut history = History::default();
    let mut step = BcdRowStep {
        x_rows,
        y_loc,
        d_offset,
        reference,
        backend,
        s,
        b,
        lam: opts.lam,
        inv_n: 1.0 / n as f64,
        gl: packed_len(sb),
        n,
        n_loc,
        p,
        rank,
        row_part,
        col_part,
        overlap: opts.overlap,
        // The one-iteration look-ahead would leave iteration k+1's
        // exchange in flight at a checkpoint boundary (and a cancelled
        // early-stop iteration must not have communicated), so it engages
        // only for fixed-length, non-checkpointed runs.
        pipeline: opts.overlap && opts.tol.is_none() && !checkpoint::active(),
        outer: opts.outer_iters(),
        sampler: BlockSampler::new(d_global, opts.seed),
        w_loc: vec![0.0; d_loc],
        alpha_loc: vec![0.0; n_loc],
        z: vec![0.0; n_loc],
        all_idx: (0..sb).collect(),
        overlap_tensor: vec![0.0; s * s * b * b],
        max_loads: Vec::new(),
        lookahead: None,
        pending: None,
        y_cols: Vec::new(),
    };
    drive(&mut step, opts, comm, &mut history)?;
    let w_full = metered_out(comm, |c| {
        let mut full = vec![0.0; d_global];
        full[d_offset..d_offset + d_loc].copy_from_slice(&step.w_loc);
        c.allreduce_sum(&mut full)?;
        Ok(full)
    })?;
    Ok(RowPrimalOutput {
        w_loc: step.w_loc,
        w_full,
        history,
        max_loads: step.max_loads,
    })
}

/// The row-layout primal method's per-iteration callbacks, including the
/// Theorem-4 redistribution and (in overlap mode) its one-iteration
/// look-ahead pipeline.
pub(crate) struct BcdRowStep<'a> {
    x_rows: &'a Matrix,
    y_loc: &'a [f64],
    d_offset: usize,
    reference: Option<&'a Reference>,
    backend: &'a mut dyn ComputeBackend,
    s: usize,
    b: usize,
    lam: f64,
    inv_n: f64,
    gl: usize,
    n: usize,
    n_loc: usize,
    p: usize,
    rank: usize,
    row_part: BlockPartition,
    col_part: BlockPartition,
    overlap: bool,
    /// Whether the one-iteration a2a look-ahead is active (overlap mode
    /// with no tolerance stop — see the module docs).
    pipeline: bool,
    outer: usize,
    sampler: BlockSampler,
    w_loc: Vec<f64>,
    alpha_loc: Vec<f64>,
    z: Vec<f64>,
    all_idx: Vec<usize>,
    overlap_tensor: Vec<f64>,
    max_loads: Vec<usize>,
    /// Overlap mode: a sample drawn ahead of the engine's `sample(k)` call
    /// (its exchange is already in flight).
    lookahead: Option<Sample>,
    /// Overlap mode: the in-flight Theorem-4 exchange for iteration `.0`.
    pending: Option<(usize, AllToAllHandle)>,
    /// Reassembled `sb × n_loc` panels keyed by outer iteration (at most
    /// two live at once under the prefetch schedule).
    y_cols: Vec<(usize, Matrix)>,
}

impl<'a> BcdRowStep<'a> {
    fn draw(&mut self, k: usize) -> Sample {
        Sample::flatten(k, self.sampler.draw_blocks(self.s, self.b), self.b)
    }

    /// Build the Theorem-4 send buffers and receive-length contracts for
    /// `smp`: the owner of sampled row i sends, to every rank q, the
    /// segment `row_i[q's column range]`. The shared seed means every rank
    /// knows the full index list and the owner map, so `recv_lens` (and
    /// the reassembly below) are deterministic.
    fn build_exchange(&self, smp: &Sample) -> Result<(Vec<Vec<f64>>, Vec<usize>, usize)> {
        let mut send: Vec<Vec<f64>> = (0..self.p).map(|_| Vec::new()).collect();
        let mut owned = 0usize;
        for &i in &smp.idx {
            if self.row_part.owner(i) == self.rank {
                owned += 1;
                let local_row = i - self.d_offset;
                for (q, dst) in send.iter_mut().enumerate() {
                    let (lo, hi) = self.col_part.range(q);
                    let start = dst.len();
                    dst.resize(start + (hi - lo), 0.0);
                    gather_row_segment(self.x_rows, local_row, lo, hi, &mut dst[start..])?;
                }
            }
        }
        // Receive-side length contract: a mis-sized payload poisons the
        // group instead of desynchronizing the reassembly.
        let mut recv_lens = vec![0usize; self.p];
        for &i in &smp.idx {
            recv_lens[self.row_part.owner(i)] += self.n_loc;
        }
        Ok((send, recv_lens, owned))
    }

    /// Measured Lemma-3 load for this iteration: max over ranks of sampled
    /// rows owned — one meter-excluded P-word allreduce. In overlap mode
    /// it runs *inside* the in-flight Theorem-4 exchange.
    fn meter_load<C: Communicator>(&mut self, comm: &mut C, owned: usize) -> Result<()> {
        let mut load_buf = vec![0.0f64; self.p];
        load_buf[self.rank] = owned as f64;
        metered_out(comm, |c| c.allreduce_sum(&mut load_buf))?;
        self.max_loads
            .push(load_buf.iter().fold(0.0f64, |a, &v| a.max(v)) as usize);
        Ok(())
    }

    /// Overlap mode: post `smp`'s exchange non-blockingly and hide the
    /// load-metering allreduce inside it (operation tags keep the two
    /// message streams apart).
    fn post_exchange<C: Communicator>(&mut self, comm: &mut C, smp: &Sample) -> Result<()> {
        let (send, recv_lens, owned) = self.build_exchange(smp)?;
        let handle = comm.iall_to_all_start(send, &recv_lens)?;
        self.pending = Some((smp.k, handle));
        self.meter_load(comm, owned)
    }

    /// Run (or complete) `smp`'s Theorem-4 exchange and reassemble its
    /// `Y_cols` panel into `self.y_cols`. In overlap mode the exchange
    /// was posted in [`CaStep::sample`] and is drained here; the blocking
    /// path meters the Lemma-3 load first, then exchanges.
    fn acquire_panel<C: Communicator>(&mut self, comm: &mut C, smp: &Sample) -> Result<()> {
        let received = if self.overlap {
            let (k, handle) = self.pending.take().ok_or_else(|| {
                Error::Runtime(
                    "bcd_row: overlap panel acquire found no posted exchange".into(),
                )
            })?;
            debug_assert_eq!(k, smp.k, "exchange/iteration mismatch");
            comm.iall_to_all_wait(handle)?
        } else {
            // Blocking path: load metering first, then the exchange.
            let (send, recv_lens, owned) = self.build_exchange(smp)?;
            self.meter_load(comm, owned)?;
            comm.all_to_all_expect(send, &recv_lens)?
        };
        self.reassemble(smp, received);
        Ok(())
    }

    /// z = y − α (this rank's column range), refreshed once per
    /// iteration before the residual kernel.
    fn refresh_z(&mut self) {
        for ((zi, yi), ai) in self.z.iter_mut().zip(self.y_loc).zip(&self.alpha_loc) {
            *zi = yi - ai;
        }
    }

    /// Contribute this rank's owned `w` entries at the sampled indices
    /// into the payload's `w` segment (zeros elsewhere; the allreduce
    /// sums the contributions into the replicated gather).
    fn fill_owned_w(&self, smp: &Sample, w_buf: &mut [f64]) {
        w_buf.fill(0.0);
        for (slot, &i) in smp.idx.iter().enumerate() {
            if self.row_part.owner(i) == self.rank {
                w_buf[slot] = self.w_loc[i - self.d_offset];
            }
        }
    }

    /// Reassemble the `sb × n_loc` column panel from the per-owner
    /// payloads: rank q's payload lists its owned sampled rows' local
    /// segments in global sample order.
    fn reassemble(&mut self, smp: &Sample, received: Vec<Vec<f64>>) {
        let sb = self.s * self.b;
        let mut panel = DenseMatrix::zeros(sb, self.n_loc);
        let mut cursor = vec![0usize; self.p];
        for (row_slot, &i) in smp.idx.iter().enumerate() {
            let owner = self.row_part.owner(i);
            let seg = &received[owner][cursor[owner]..cursor[owner] + self.n_loc];
            panel.data_mut()[row_slot * self.n_loc..(row_slot + 1) * self.n_loc]
                .copy_from_slice(seg);
            cursor[owner] += self.n_loc;
        }
        self.y_cols.push((smp.k, Matrix::Dense(panel)));
    }
}

/// Look up iteration `k`'s reassembled panel. A free function (not a
/// method) so callers keep field-precise borrows: the panel reference
/// pins only `y_cols` while the mutable backend call runs.
fn find_panel(y_cols: &[(usize, Matrix)], k: usize) -> Result<&Matrix> {
    y_cols
        .iter()
        .find(|(kk, _)| *kk == k)
        .map(|(_, panel)| panel)
        .ok_or_else(|| {
            Error::Runtime(format!(
                "bcd_row: Y_cols panel for iteration {k} missing (exchange never drained?)"
            ))
        })
}

impl<C: Communicator> CaStep<C> for BcdRowStep<'_> {
    fn payload_split(&self) -> (usize, usize) {
        // [G | r | w_blk] — the Theorem-4 layout's packed payload,
        // `sb(sb+1)/2 + 2sb` words: G rides as its lower triangle, and w
        // at the sampled indices is contributed by owners (zeros
        // elsewhere) and summed — piggybacking the gather on the existing
        // collective instead of a separate broadcast.
        (self.gl, 2 * self.s * self.b)
    }

    fn prefetch_gram(&self) -> bool {
        // The panel exchange + reassembly + Gram compute are all pure
        // functions of X and the shared-seed sample stream, so the engine
        // may run them one iteration ahead, under the in-flight [G|r|w]
        // reduction — unless a tolerance stop is configured (a cancelled
        // iteration must not have communicated; see the module docs).
        self.pipeline
    }

    fn sample(&mut self, comm: &mut C, k: usize) -> Result<Sample> {
        if let Some(ahead) = self.lookahead.take() {
            debug_assert_eq!(ahead.k, k, "look-ahead sample out of order");
            return Ok(ahead);
        }
        let smp = self.draw(k);
        if self.overlap {
            // First iteration (no look-ahead yet): post its exchange now.
            self.post_exchange(comm, &smp)?;
        }
        Ok(smp)
    }

    fn local_gram(&mut self, comm: &mut C, smp: &Sample, head: &mut [f64]) -> Result<()> {
        self.acquire_panel(comm, smp)?;
        if self.pipeline && smp.k + 1 < self.outer {
            // Look-ahead: draw iteration k+1 and post its exchange before
            // computing G_k, so the redistribution payloads fly while this
            // rank crunches the Gram (and, one level up, while the
            // engine's [G|r|w] reduction for iteration k−1 is in flight).
            let nxt = self.draw(smp.k + 1);
            self.post_exchange(comm, &nxt)?;
            self.lookahead = Some(nxt);
        }
        let panel = find_panel(&self.y_cols, smp.k)?;
        self.backend.gram_only(panel, &self.all_idx, head)
    }

    fn local_state(&mut self, smp: &Sample, tail: &mut [f64]) -> Result<()> {
        self.refresh_z();
        let sb = self.s * self.b;
        let (r_buf, w_buf) = tail.split_at_mut(sb);
        {
            let panel = find_panel(&self.y_cols, smp.k)?;
            self.backend
                .resid_only(panel, &self.all_idx, &self.z, r_buf)?;
        }
        self.fill_owned_w(smp, w_buf);
        Ok(())
    }

    fn local_payload(
        &mut self,
        comm: &mut C,
        smp: &Sample,
        head: &mut [f64],
        tail: &mut [f64],
    ) -> Result<()> {
        // Same-iteration panel + gram + residual (blocking and
        // non-prefetch overlap schedules): exchange, then one fused
        // backend call, exactly like the pre-engine loop.
        self.acquire_panel(comm, smp)?;
        self.refresh_z();
        let sb = self.s * self.b;
        let (r_buf, w_buf) = tail.split_at_mut(sb);
        {
            let panel = find_panel(&self.y_cols, smp.k)?;
            self.backend
                .gram_resid(panel, &self.all_idx, &self.z, head, r_buf)?;
        }
        self.fill_owned_w(smp, w_buf);
        Ok(())
    }

    fn hidden_work(&mut self, smp: &Sample) -> Result<()> {
        overlap_tensor_into(&smp.blocks, &mut self.overlap_tensor);
        Ok(())
    }

    fn inner_solve(&mut self, _smp: &Sample, head: &[f64], tail: &[f64]) -> Result<Vec<f64>> {
        let sb = self.s * self.b;
        let (r_buf, w_buf) = tail.split_at(sb);
        self.backend.ca_inner_solve(
            self.s,
            self.b,
            head,
            r_buf,
            w_buf,
            &self.overlap_tensor,
            self.lam,
            self.inv_n,
        )
    }

    fn apply(&mut self, smp: &Sample, deltas: &[f64]) -> Result<()> {
        // Deferred updates: w on owners, α on column ranges (both local).
        for (slot, &i) in smp.idx.iter().enumerate() {
            if self.row_part.owner(i) == self.rank {
                self.w_loc[i - self.d_offset] += deltas[slot];
            }
        }
        // Take the panel out for the α update; it is dead afterwards (at
        // most one other panel — the prefetched one — stays live).
        let pos = self
            .y_cols
            .iter()
            .position(|(kk, _)| *kk == smp.k)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "bcd_row: panel for iteration {} missing in apply",
                    smp.k
                ))
            })?;
        let (_, panel) = self.y_cols.swap_remove(pos);
        self.backend
            .alpha_update(&panel, &self.all_idx, deltas, &mut self.alpha_loc)?;
        Ok(())
    }

    fn record(&mut self, comm: &mut C, history: &mut History, h_now: usize) -> Result<()> {
        record(
            history,
            h_now,
            &self.w_loc,
            &self.alpha_loc,
            self.y_loc,
            self.n,
            self.lam,
            self.reference,
            comm,
        )
    }

    fn converged(&self, history: &History, tol: f64) -> bool {
        self.reference.is_some() && history.final_obj_err() <= tol
    }

    fn flush(&mut self, comm: &mut C) -> Result<()> {
        // Early stop can leave a look-ahead exchange in flight: drain it
        // so later collectives (the final w gather) see a clean stream.
        if let Some((_, handle)) = self.pending.take() {
            comm.iall_to_all_wait(handle)?;
        }
        self.lookahead = None;
        self.y_cols.clear();
        Ok(())
    }

    fn ckpt_kind(&self) -> &'static str {
        "bcd_row"
    }

    fn save_state(&self, ckpt: &mut Checkpoint) -> Result<()> {
        // Capture runs at an outer boundary on the non-pipelined
        // schedules, where no exchange is in flight and every panel is
        // consumed — the mutable state is the sampler RNG, the two
        // partitioned iterates, and the measured Lemma-3 load series.
        debug_assert!(self.pending.is_none() && self.lookahead.is_none());
        ckpt.rng = self.sampler.rng_state().to_vec();
        ckpt.push_f64("w_loc", &self.w_loc);
        ckpt.push_f64("alpha_loc", &self.alpha_loc);
        let loads: Vec<u64> = self.max_loads.iter().map(|&l| l as u64).collect();
        ckpt.push_u64("max_loads", &loads);
        Ok(())
    }

    fn restore_state(&mut self, ckpt: &Checkpoint) -> Result<()> {
        self.sampler.set_rng_state(ckpt.rng_words()?);
        ckpt.read_f64_into("w_loc", &mut self.w_loc)?;
        ckpt.read_f64_into("alpha_loc", &mut self.alpha_loc)?;
        self.max_loads = ckpt.get_u64("max_loads")?.iter().map(|&l| l as usize).collect();
        Ok(())
    }
}

fn gather_row_segment(
    x: &Matrix,
    row: usize,
    lo: usize,
    hi: usize,
    out: &mut [f64],
) -> Result<()> {
    match x {
        Matrix::Dense(m) => {
            out.copy_from_slice(&m.row(row)[lo..hi]);
        }
        Matrix::Csr(m) => {
            out.fill(0.0);
            let (cols, vals) = m.row(row);
            for (&c, &v) in cols.iter().zip(vals) {
                let c = c as usize;
                if c >= lo && c < hi {
                    out[c - lo] = v;
                }
            }
        }
    }
    Ok(())
}

/// Distributed metric evaluation (same quantities as the matched layout;
/// here w is partitioned so its norm and error are allreduced too).
#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w_loc: &[f64],
    alpha_loc: &[f64],
    y_loc: &[f64],
    n: usize,
    lam: f64,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<()> {
    let Some(r) = reference else { return Ok(()) };
    let rank = comm.rank();
    let p = comm.size();
    let d_part = BlockPartition::new(r.w_opt.len(), p);
    let (d_lo, _d_hi) = d_part.range(rank);
    let sums = metered_out(comm, |c| {
        let mut part = [
            alpha_loc
                .iter()
                .zip(y_loc)
                .map(|(a, y)| (a - y) * (a - y))
                .sum::<f64>(),
            w_loc.iter().map(|v| v * v).sum::<f64>(),
            w_loc
                .iter()
                .zip(&r.w_opt[d_lo..d_lo + w_loc.len()])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>(),
        ];
        c.allreduce_sum(&mut part)?;
        Ok(part)
    })?;
    let f_alg = objective_value(sums[0], sums[1], n, lam);
    let w_opt_norm_sq: f64 = r.w_opt.iter().map(|v| v * v).sum();
    history.records.push(IterRecord {
        iter,
        obj_err: relative_objective_error(f_alg, r.f_opt),
        sol_err: (sums[2] / w_opt_norm_sq.max(1e-300)).sqrt(),
    });
    let _ = relative_solution_error; // (replicated-w helper unused here)
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::thread::run_spmd;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::solvers::bcd;

    fn toy(d: usize, n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut st = seed | 1;
        let data: Vec<f64> = (0..d * n)
            .map(|_| {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                (st as f64 / u64::MAX as f64) - 0.5
            })
            .collect();
        let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
        let mut y = vec![0.0; n];
        x.matvec_t(&vec![1.0; d], &mut y).unwrap();
        (x, y)
    }

    /// The Theorem-4/8 layout must produce the SAME trajectory as the
    /// matched layout — only the communication pattern changes.
    #[test]
    fn row_layout_matches_column_layout() {
        let (x, y) = toy(12, 48, 5);
        let opts = SolverOpts {
            b: 3,
            s: 4,
            lam: 0.2,
            iters: 24,
            seed: 11,
            record_every: 0,
            track_gram_cond: false,
            tol: None,
            overlap: false,
            ..Default::default()
        };
        // Matched layout, serial.
        let mut be = NativeBackend::new();
        let mut c = SerialComm::new();
        let w_col = bcd::run(&x, &y, 48, &opts, None, &mut c, &mut be).unwrap().w;

        // Row layout over P ranks, blocking and overlapped comm paths.
        for (p, overlap) in [(1usize, false), (3, false), (4, false), (4, true)] {
            let row_part = BlockPartition::new(12, p);
            let col_part = BlockPartition::new(48, p);
            let mut opts2 = opts.clone();
            opts2.overlap = overlap;
            let x2 = &x;
            let y2 = &y;
            let outs = run_spmd(p, move |rank, comm| {
                let (rlo, rhi) = row_part.range(rank);
                let (clo, chi) = col_part.range(rank);
                // Build the rank's row slab.
                let idx: Vec<usize> = (rlo..rhi).collect();
                let mut slab = vec![0.0; idx.len() * 48];
                x2.gather_rows(&idx, &mut slab).unwrap();
                let slab = Matrix::Dense(DenseMatrix::from_vec(idx.len(), 48, slab));
                let mut be = NativeBackend::new();
                run(
                    &slab,
                    &y2[clo..chi],
                    12,
                    rlo,
                    &opts2,
                    None,
                    comm,
                    &mut be,
                )
                .unwrap()
            });
            let w_row = &outs[0].w_full;
            for (i, (a, b)) in w_col.iter().zip(w_row).enumerate() {
                assert!(
                    (a - b).abs() < 1e-10,
                    "P={p} w[{i}]: col {a} vs row {b}"
                );
            }
            // Every outer iteration performed one all-to-all.
            assert_eq!(outs[0].history.meter.all_to_alls, 24 / 4, "P={p}");
        }
    }

    /// Satellite acceptance: the look-ahead a2a pipeline (overlap mode)
    /// is bitwise-equivalent to the blocking path SPMD — trajectories,
    /// measured Lemma-3 loads, and wire counts all identical.
    #[test]
    fn overlapped_a2a_pipeline_is_bitwise_equal_to_blocking() {
        let (x, y) = toy(16, 40, 3);
        let p = 4usize;
        let mk = |overlap: bool| SolverOpts {
            b: 4,
            s: 2,
            lam: 0.15,
            iters: 16,
            seed: 9,
            record_every: 0,
            overlap,
            ..Default::default()
        };
        let row_part = BlockPartition::new(16, p);
        let col_part = BlockPartition::new(40, p);
        let x2 = &x;
        let y2 = &y;
        let mut runs = Vec::new();
        for overlap in [false, true] {
            let opts = mk(overlap);
            let outs = run_spmd(p, move |rank, comm| {
                let (rlo, rhi) = row_part.range(rank);
                let (clo, chi) = col_part.range(rank);
                let idx: Vec<usize> = (rlo..rhi).collect();
                let mut slab = vec![0.0; idx.len() * 40];
                x2.gather_rows(&idx, &mut slab).unwrap();
                let slab = Matrix::Dense(DenseMatrix::from_vec(idx.len(), 40, slab));
                let mut be = NativeBackend::new();
                run(&slab, &y2[clo..chi], 16, rlo, &opts, None, comm, &mut be).unwrap()
            });
            runs.push(outs);
        }
        for (rank, (ob, oo)) in runs[0].iter().zip(&runs[1]).enumerate() {
            assert_eq!(ob.w_full, oo.w_full, "rank {rank}: trajectory diverged");
            assert_eq!(ob.w_loc, oo.w_loc, "rank {rank}: w_loc diverged");
            assert_eq!(ob.max_loads, oo.max_loads, "rank {rank}: loads diverged");
            let (mb, mo) = (&ob.history.meter, &oo.history.meter);
            assert_eq!(mb.allreduces, mo.allreduces, "rank {rank}");
            assert_eq!(mb.all_to_alls, mo.all_to_alls, "rank {rank}");
            assert_eq!(mb.msgs, mo.msgs, "rank {rank}");
            assert_eq!(mb.words, mo.words, "rank {rank}");
            assert_eq!(mb.recv_msgs, mo.recv_msgs, "rank {rank}");
            assert_eq!(mb.recv_words, mo.recv_words, "rank {rank}");
        }
    }

    /// Lemma 3: the measured max load stays far below b (and ≥ ⌈sb/P⌉).
    #[test]
    fn measured_max_load_respects_lemma3_regime() {
        let (x, y) = toy(64, 40, 9);
        let p = 8usize;
        let opts = SolverOpts {
            b: 8,
            s: 2,
            lam: 0.3,
            iters: 40,
            seed: 3,
            record_every: 0,
            track_gram_cond: false,
            tol: None,
            overlap: false,
            ..Default::default()
        };
        let row_part = BlockPartition::new(64, p);
        let col_part = BlockPartition::new(40, p);
        let x2 = &x;
        let y2 = &y;
        let opts2 = opts.clone();
        let outs = run_spmd(p, move |rank, comm| {
            let (rlo, rhi) = row_part.range(rank);
            let (clo, chi) = col_part.range(rank);
            let idx: Vec<usize> = (rlo..rhi).collect();
            let mut slab = vec![0.0; idx.len() * 40];
            x2.gather_rows(&idx, &mut slab).unwrap();
            let slab = Matrix::Dense(DenseMatrix::from_vec(idx.len(), 40, slab));
            let mut be = NativeBackend::new();
            run(&slab, &y2[clo..chi], 64, rlo, &opts2, None, comm, &mut be).unwrap()
        });
        let sb = 16usize;
        for loads in outs.iter().map(|o| &o.max_loads) {
            assert_eq!(loads.len(), 20);
            for &l in loads {
                assert!(l >= sb.div_ceil(p), "max load below the mean?");
                assert!(l <= sb, "max load exceeds total samples");
            }
        }
        // With sb=16 balls over 8 bins, the typical max should be well
        // under sb (Lemma 3: O(ln b/ln ln b) above the mean).
        let median_of_max = {
            let mut all: Vec<usize> = outs[0].max_loads.clone();
            all.sort_unstable();
            all[all.len() / 2]
        };
        assert!(median_of_max <= 8, "median max load {median_of_max}");
    }
}
