//! BCD / CA-BCD under the **mismatched** 1D-block-row layout
//! (Theorems 4 and 8): X's rows (features) are partitioned, so the sampled
//! `sb × n` block is scattered across owners and must be converted to the
//! 1D-block-column layout by an **all-to-all** before every Gram
//! computation — the paper's load-balancing redistribution, whose volume is
//! bounded by the Lemma-3 balls-into-bins maximum load.
//!
//! Layout duals of the matched case: vectors in `R^d` (w) are partitioned,
//! vectors in `R^n` (y, α) are partitioned too (each rank owns a column
//! range); the inner solve still runs replicated, fed by the allreduce.
//! The trajectory is **identical** to the block-column solver under the
//! same seed — asserted by the layout-equivalence integration test — only
//! the communication pattern differs (extra all-to-all per outer
//! iteration, exactly Theorem 8's `W` term).
//!
//! With [`SolverOpts::overlap`], the Theorem-4 all-to-all itself is
//! pipelined: sends post through `iall_to_all_start`, the Lemma-3
//! load-metering allreduce runs while the exchange is in flight
//! (operation tags keep the streams apart), and `iall_to_all_wait` drains
//! the receives — in addition to the existing overlap of the
//! overlap-tensor assembly behind the `[G|r|w]` iallreduce. Both overlaps
//! are bitwise-identical to the blocking path.

use crate::comm::Communicator;
use crate::error::{Error, Result};
use crate::gram::ComputeBackend;
use crate::linalg::packed::packed_len;
use crate::matrix::{DenseMatrix, Matrix};
use crate::metrics::{
    relative_objective_error, relative_solution_error, History, IterRecord, Reference,
};
use crate::partition::BlockPartition;
use crate::sampling::{overlap_tensor_into, BlockSampler};
use crate::solvers::common::{metered_out, objective_value, should_record, SolverOpts};

/// Output of the row-layout primal solver.
#[derive(Clone, Debug)]
pub struct RowPrimalOutput {
    /// This rank's slice of w (feature range `d_range`).
    pub w_loc: Vec<f64>,
    /// Full w (assembled once at the end, metric path).
    pub w_full: Vec<f64>,
    pub history: History,
    /// Max sampled rows owned by any single rank, per outer iteration —
    /// the measured Lemma-3 load (tested against O(ln b / ln ln b)).
    pub max_loads: Vec<usize>,
}

/// Run BCD / CA-BCD with X stored 1D-block-row.
///
/// * `x_rows` — this rank's `d_loc × n` slab of X (full rows).
/// * `y_loc` — this rank's slice of y for the column range it owns
///   (column ranges are the canonical `BlockPartition::new(n, P)`).
/// * `d_global`, `d_offset` — feature partition bookkeeping.
#[allow(clippy::too_many_arguments)]
pub fn run<C: Communicator>(
    x_rows: &Matrix,
    y_loc: &[f64],
    d_global: usize,
    d_offset: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<RowPrimalOutput> {
    if !opts.reg.is_exact_l2() {
        return Err(Error::InvalidArg(
            "bcd_row supports reg = l2 only; prox regularizers run through \
             solvers::bcd / solvers::bdcd (matched layouts)"
                .into(),
        ));
    }
    let d_loc = x_rows.rows();
    let n = x_rows.cols();
    opts.validate(d_global)?;
    let p = comm.size();
    let rank = comm.rank();
    let row_part = BlockPartition::new(d_global, p);
    let col_part = BlockPartition::new(n, p);
    let (col_lo, col_hi) = col_part.range(rank);
    let n_loc = col_hi - col_lo;
    if y_loc.len() != n_loc {
        return Err(Error::Shape(format!(
            "row-layout: y_loc {} != column range {}",
            y_loc.len(),
            n_loc
        )));
    }
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let inv_n = 1.0 / n as f64;
    let lam = opts.lam;

    let mut w_loc = vec![0.0; d_loc];
    let mut alpha_loc = vec![0.0; n_loc];
    let mut history = History::default();
    let mut max_loads = Vec::new();

    // [G | r | w_blk] allreduce payload — the Theorem-4 layout's packed
    // equivalent, `sb(sb+1)/2 + 2sb` words: G rides as its lower triangle,
    // and w at the sampled indices is contributed by owners (zeros
    // elsewhere) and summed — piggybacking the gather on the existing
    // collective instead of a separate broadcast.
    let gl = packed_len(sb);
    let mut buf = vec![0.0; gl + sb + sb];
    let mut z = vec![0.0; n_loc];
    let mut overlap = vec![0.0; s * s * b * b];
    let mut deltas_scratch: Vec<f64>;

    let mut sampler = BlockSampler::new(d_global, opts.seed);

    record(
        &mut history, 0, &w_loc, &alpha_loc, y_loc, n, lam, reference, comm,
    )?;

    let outer = opts.outer_iters();
    'outer_loop: for k in 0..outer {
        let blocks = sampler.draw_blocks(s, b);
        let flat: Vec<usize> = blocks.iter().flatten().copied().collect();

        // ---- Theorem-4 all-to-all: row slabs → column slabs -------------
        // Owner of sampled row i sends, to every rank q, the segment
        // row_i[q's column range]; everyone reassembles Y_cols (sb × n_loc)
        // in global sample order (deterministic — shared seed means every
        // rank knows the full index list and the owner map).
        let mut send: Vec<Vec<f64>> = (0..p).map(|_| Vec::new()).collect();
        let mut owned = 0usize;
        for &i in &flat {
            if row_part.owner(i) == rank {
                owned += 1;
                let local_row = i - d_offset;
                for (q, dst) in send.iter_mut().enumerate() {
                    let (lo, hi) = col_part.range(q);
                    let start = dst.len();
                    dst.resize(start + (hi - lo), 0.0);
                    gather_row_segment(x_rows, local_row, lo, hi, &mut dst[start..])?;
                }
            }
        }
        // Receive-side length contract: the shared seed means every rank
        // knows exactly how many sampled rows each owner contributes, so a
        // mis-sized payload poisons the group instead of desynchronizing
        // the reassembly below.
        let mut recv_lens = vec![0usize; p];
        for &i in &flat {
            recv_lens[row_part.owner(i)] += n_loc;
        }
        // Measured Lemma-3 load: max over ranks of sampled rows owned —
        // one meter-excluded P-word allreduce. With `opts.overlap` it runs
        // *inside* the in-flight Theorem-4 all-to-all (the non-blocking
        // start/wait pair; operation tags keep the two message streams
        // apart), hiding the metering latency behind the redistribution.
        // Payloads and per-source ordering are unchanged, so the
        // trajectory and the measured loads are bitwise identical to the
        // blocking path.
        let mut load_buf = vec![0.0f64; p];
        load_buf[rank] = owned as f64;
        let received = if opts.overlap {
            let handle = comm.iall_to_all_start(send, &recv_lens)?;
            metered_out(comm, |c| c.allreduce_sum(&mut load_buf))?;
            comm.iall_to_all_wait(handle)?
        } else {
            metered_out(comm, |c| c.allreduce_sum(&mut load_buf))?;
            comm.all_to_all_expect(send, &recv_lens)?
        };
        max_loads.push(load_buf.iter().fold(0.0f64, |a, &v| a.max(v)) as usize);
        // Reassemble: rank q's payload lists its owned sampled rows' local
        // segments in global sample order.
        let mut y_cols = DenseMatrix::zeros(sb, n_loc);
        let mut cursor = vec![0usize; p];
        for (row_slot, &i) in flat.iter().enumerate() {
            let owner = row_part.owner(i);
            let seg = &received[owner][cursor[owner]..cursor[owner] + n_loc];
            y_cols.data_mut()[row_slot * n_loc..(row_slot + 1) * n_loc].copy_from_slice(seg);
            cursor[owner] += n_loc;
        }
        let y_cols = Matrix::Dense(y_cols);

        // ---- From here the matched-layout algorithm proceeds -----------
        for ((zi, yi), ai) in z.iter_mut().zip(y_loc).zip(&alpha_loc) {
            *zi = yi - ai;
        }
        let all_idx: Vec<usize> = (0..sb).collect();
        {
            let (g_buf, rest) = buf.split_at_mut(gl);
            let (r_buf, w_buf) = rest.split_at_mut(sb);
            backend.gram_resid(&y_cols, &all_idx, &z, g_buf, r_buf)?;
            // Contribute owned w entries for the replicated inner solve.
            w_buf.fill(0.0);
            for (slot, &i) in flat.iter().enumerate() {
                if row_part.owner(i) == rank {
                    w_buf[slot] = w_loc[i - d_offset];
                }
            }
        }
        // THE allreduce of this outer iteration. In overlap mode the
        // overlap-tensor assembly (independent of the reduced values) is
        // hidden behind the in-flight reduction; the payload and reduction
        // algorithm are unchanged, so the trajectory is bitwise identical.
        if opts.overlap {
            // Move the hoisted buffer into the handle and take it back
            // reduced — no payload copies on the hot path.
            let handle = comm.iallreduce_start(std::mem::take(&mut buf))?;
            overlap_tensor_into(&blocks, &mut overlap);
            buf = comm.iallreduce_wait(handle)?;
        } else {
            comm.allreduce_sum(&mut buf)?;
            overlap_tensor_into(&blocks, &mut overlap);
        }
        {
            let (g_buf, rest) = buf.split_at(gl);
            let (r_buf, w_buf) = rest.split_at(sb);
            deltas_scratch =
                backend.ca_inner_solve(s, b, g_buf, r_buf, w_buf, &overlap, lam, inv_n)?;
        }

        // Deferred updates: w on owners, α on column ranges (both local).
        for (slot, &i) in flat.iter().enumerate() {
            if row_part.owner(i) == rank {
                w_loc[i - d_offset] += deltas_scratch[slot];
            }
        }
        backend.alpha_update(&y_cols, &all_idx, &deltas_scratch, &mut alpha_loc)?;

        let h_now = (k + 1) * s;
        history.iters = h_now;
        if should_record(h_now, s, opts) || k + 1 == outer {
            record(
                &mut history, h_now, &w_loc, &alpha_loc, y_loc, n, lam, reference, comm,
            )?;
            if let (Some(tol), Some(_)) = (opts.tol, reference) {
                if history.final_obj_err() <= tol {
                    break 'outer_loop;
                }
            }
        }
    }

    history.meter = *comm.meter();
    let w_full = metered_out(comm, |c| {
        let mut full = vec![0.0; d_global];
        full[d_offset..d_offset + d_loc].copy_from_slice(&w_loc);
        c.allreduce_sum(&mut full)?;
        Ok(full)
    })?;
    Ok(RowPrimalOutput {
        w_loc,
        w_full,
        history,
        max_loads,
    })
}

fn gather_row_segment(
    x: &Matrix,
    row: usize,
    lo: usize,
    hi: usize,
    out: &mut [f64],
) -> Result<()> {
    match x {
        Matrix::Dense(m) => {
            out.copy_from_slice(&m.row(row)[lo..hi]);
        }
        Matrix::Csr(m) => {
            out.fill(0.0);
            let (cols, vals) = m.row(row);
            for (&c, &v) in cols.iter().zip(vals) {
                let c = c as usize;
                if c >= lo && c < hi {
                    out[c - lo] = v;
                }
            }
        }
    }
    Ok(())
}

/// Distributed metric evaluation (same quantities as the matched layout;
/// here w is partitioned so its norm and error are allreduced too).
#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w_loc: &[f64],
    alpha_loc: &[f64],
    y_loc: &[f64],
    n: usize,
    lam: f64,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<()> {
    let Some(r) = reference else { return Ok(()) };
    let rank = comm.rank();
    let p = comm.size();
    let d_part = BlockPartition::new(r.w_opt.len(), p);
    let (d_lo, _d_hi) = d_part.range(rank);
    let sums = metered_out(comm, |c| {
        let mut part = [
            alpha_loc
                .iter()
                .zip(y_loc)
                .map(|(a, y)| (a - y) * (a - y))
                .sum::<f64>(),
            w_loc.iter().map(|v| v * v).sum::<f64>(),
            w_loc
                .iter()
                .zip(&r.w_opt[d_lo..d_lo + w_loc.len()])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>(),
        ];
        c.allreduce_sum(&mut part)?;
        Ok(part)
    })?;
    let f_alg = objective_value(sums[0], sums[1], n, lam);
    let w_opt_norm_sq: f64 = r.w_opt.iter().map(|v| v * v).sum();
    history.records.push(IterRecord {
        iter,
        obj_err: relative_objective_error(f_alg, r.f_opt),
        sol_err: (sums[2] / w_opt_norm_sq.max(1e-300)).sqrt(),
    });
    let _ = relative_solution_error; // (replicated-w helper unused here)
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::thread::run_spmd;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::solvers::bcd;

    fn toy(d: usize, n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut st = seed | 1;
        let data: Vec<f64> = (0..d * n)
            .map(|_| {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                (st as f64 / u64::MAX as f64) - 0.5
            })
            .collect();
        let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
        let mut y = vec![0.0; n];
        x.matvec_t(&vec![1.0; d], &mut y).unwrap();
        (x, y)
    }

    /// The Theorem-4/8 layout must produce the SAME trajectory as the
    /// matched layout — only the communication pattern changes.
    #[test]
    fn row_layout_matches_column_layout() {
        let (x, y) = toy(12, 48, 5);
        let opts = SolverOpts {
            b: 3,
            s: 4,
            lam: 0.2,
            iters: 24,
            seed: 11,
            record_every: 0,
            track_gram_cond: false,
            tol: None,
            overlap: false,
            ..Default::default()
        };
        // Matched layout, serial.
        let mut be = NativeBackend::new();
        let mut c = SerialComm::new();
        let w_col = bcd::run(&x, &y, 48, &opts, None, &mut c, &mut be).unwrap().w;

        // Row layout over P ranks, blocking and overlapped comm paths.
        for (p, overlap) in [(1usize, false), (3, false), (4, false), (4, true)] {
            let row_part = BlockPartition::new(12, p);
            let col_part = BlockPartition::new(48, p);
            let mut opts2 = opts.clone();
            opts2.overlap = overlap;
            let x2 = &x;
            let y2 = &y;
            let outs = run_spmd(p, move |rank, comm| {
                let (rlo, rhi) = row_part.range(rank);
                let (clo, chi) = col_part.range(rank);
                // Build the rank's row slab.
                let idx: Vec<usize> = (rlo..rhi).collect();
                let mut slab = vec![0.0; idx.len() * 48];
                x2.gather_rows(&idx, &mut slab).unwrap();
                let slab = Matrix::Dense(DenseMatrix::from_vec(idx.len(), 48, slab));
                let mut be = NativeBackend::new();
                run(
                    &slab,
                    &y2[clo..chi],
                    12,
                    rlo,
                    &opts2,
                    None,
                    comm,
                    &mut be,
                )
                .unwrap()
            });
            let w_row = &outs[0].w_full;
            for (i, (a, b)) in w_col.iter().zip(w_row).enumerate() {
                assert!(
                    (a - b).abs() < 1e-10,
                    "P={p} w[{i}]: col {a} vs row {b}"
                );
            }
            // Every outer iteration performed one all-to-all.
            assert_eq!(outs[0].history.meter.all_to_alls, 24 / 4, "P={p}");
        }
    }

    /// Lemma 3: the measured max load stays far below b (and ≥ ⌈sb/P⌉).
    #[test]
    fn measured_max_load_respects_lemma3_regime() {
        let (x, y) = toy(64, 40, 9);
        let p = 8usize;
        let opts = SolverOpts {
            b: 8,
            s: 2,
            lam: 0.3,
            iters: 40,
            seed: 3,
            record_every: 0,
            track_gram_cond: false,
            tol: None,
            overlap: false,
            ..Default::default()
        };
        let row_part = BlockPartition::new(64, p);
        let col_part = BlockPartition::new(40, p);
        let x2 = &x;
        let y2 = &y;
        let opts2 = opts.clone();
        let outs = run_spmd(p, move |rank, comm| {
            let (rlo, rhi) = row_part.range(rank);
            let (clo, chi) = col_part.range(rank);
            let idx: Vec<usize> = (rlo..rhi).collect();
            let mut slab = vec![0.0; idx.len() * 40];
            x2.gather_rows(&idx, &mut slab).unwrap();
            let slab = Matrix::Dense(DenseMatrix::from_vec(idx.len(), 40, slab));
            let mut be = NativeBackend::new();
            run(&slab, &y2[clo..chi], 64, rlo, &opts2, None, comm, &mut be).unwrap()
        });
        let sb = 16usize;
        for loads in outs.iter().map(|o| &o.max_loads) {
            assert_eq!(loads.len(), 20);
            for &l in loads {
                assert!(l >= sb.div_ceil(p), "max load below the mean?");
                assert!(l <= sb, "max load exceeds total samples");
            }
        }
        // With sb=16 balls over 8 bins, the typical max should be well
        // under sb (Lemma 3: O(ln b/ln ln b) above the mean).
        let median_of_max = {
            let mut all: Vec<usize> = outs[0].max_loads.clone();
            all.sort_unstable();
            all[all.len() / 2]
        };
        assert!(median_of_max <= 8, "median max load {median_of_max}");
    }
}
