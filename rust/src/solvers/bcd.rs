//! Primal block coordinate descent — Algorithm 1 (`s = 1`) and its
//! communication-avoiding unrolling, Algorithm 2 (`s > 1`).
//!
//! SPMD over a 1D-block-column partition of `X ∈ R^{d×n}`: each rank holds
//! `X_loc = X[:, lo..hi]`, the matching slices of `y` and `α = Xᵀw`, and a
//! full replica of `w`. One outer iteration:
//!
//! 1. every rank draws the same `s` size-`b` row blocks (shared seed — no
//!    communication),
//! 2. computes its raw partial `G = Y_loc Y_locᵀ` (packed lower triangle),
//!    `r = Y_loc (y−α)_loc` through the pluggable [`ComputeBackend`]
//!    (native Rust or the AOT Pallas artifact via PJRT),
//! 3. **one allreduce** of the `(sb(sb+1)/2 + sb)`-word packed `[G|r]`
//!    buffer — the only communication of the outer iteration, giving the
//!    Θ(s) latency saving (G is symmetric, so only its triangle rides the
//!    wire; the inner solve indexes the triangle directly),
//! 4. solves the `s` deferred `b×b` subproblems redundantly (eq. 8),
//! 5. applies the deferred updates: `w[I_t] += Δ_t`, `α_loc += Y_locᵀ δ`.
//!
//! With [`SolverOpts::overlap`] the same iteration is software-pipelined:
//! the `[G_k | r_k]` buffer reduces through the non-blocking allreduce
//! while the rank computes `G_{k+1}` (legal because G depends only on X
//! and the shared-seed sample stream, never on the evolving α/w state) and
//! assembles the overlap tensor. Still exactly one collective per outer
//! iteration, same payload, same reduction algorithm — the trajectory is
//! **bitwise identical** to the blocking path (asserted by integration
//! test) while the dominant local flops hide the reduction latency.

use crate::comm::Communicator;
use crate::error::Result;
use crate::gram::ComputeBackend;
use crate::linalg::packed::packed_len;
use crate::matrix::Matrix;
use crate::metrics::{
    relative_objective_error, relative_solution_error, History, IterRecord, Reference,
};
use crate::sampling::{overlap_tensor_into, BlockSampler};
use crate::solvers::common::{
    cond_stride, flatten_blocks, metered_out, objective_value, packed_gram_cond,
    should_record, PrimalOutput, SolverOpts,
};

/// Run BCD / CA-BCD on this rank's shard.
///
/// * `a_loc` — `d × n_loc` local column block of X.
/// * `y_loc` — local slice of the labels.
/// * `n_global` — total number of data points n.
/// * `reference` — optional `w_opt` ground truth for error recording.
#[allow(clippy::too_many_arguments)]
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<PrimalOutput> {
    if !opts.reg.is_exact_l2() {
        // Non-smooth regularizer: the CA-Prox loop (same packed [G|r]
        // payload and H/s collectives; prox certificates instead of the
        // ridge reference errors — `reference` does not apply there).
        return crate::prox::bcd::run(a_loc, y_loc, n_global, opts, comm, backend);
    }
    if opts.overlap {
        return run_overlapped(a_loc, y_loc, n_global, opts, reference, comm, backend);
    }
    let d = a_loc.rows();
    let n_loc = a_loc.cols();
    opts.validate(d)?;
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let inv_n = 1.0 / n_global as f64;
    let lam = opts.lam;

    let mut w = vec![0.0; d];
    let mut alpha_loc = vec![0.0; n_loc];
    let mut history = History::default();

    // Scratch buffers hoisted out of the iteration loop (no allocation on
    // the hot path; see EXPERIMENTS.md §Perf).
    let gl = packed_len(sb);
    let mut buf = vec![0.0; gl + sb]; // packed [G | r] allreduce payload
    let mut z = vec![0.0; n_loc];
    let mut w_blocks = vec![0.0; sb];
    let mut gram_scaled = vec![0.0; sb * sb];
    let mut idx_flat = vec![0usize; sb];
    let mut overlap = vec![0.0; s * s * b * b];

    let mut sampler = BlockSampler::new(d, opts.seed);

    record(
        &mut history,
        0,
        &w,
        &alpha_loc,
        y_loc,
        n_global,
        lam,
        reference,
        comm,
    )?;

    let outer = opts.outer_iters();
    // Condition tracking samples ~16 outer iterations for large sb —
    // the reported min/median/max statistics are over those samples
    // (estimator: power + inverse-power, linalg::cond).
    let stride = cond_stride(sb, outer);
    'outer_loop: for k in 0..outer {
        let blocks = sampler.draw_blocks(s, b);
        flatten_blocks(&blocks, b, &mut idx_flat);

        // z = y − α (local slice).
        for ((zi, yi), ai) in z.iter_mut().zip(y_loc).zip(&alpha_loc) {
            *zi = yi - ai;
        }

        // Raw partial Gram + residual through the backend (the L1 hot spot).
        let (g_buf, r_buf) = buf.split_at_mut(gl);
        backend.gram_resid(a_loc, &idx_flat, &z, g_buf, r_buf)?;

        // THE communication of this outer iteration.
        comm.allreduce_sum(&mut buf)?;

        if opts.track_gram_cond && k % stride == 0 {
            // Condition number of G = (1/n)·YYᵀ + λI (paper Figs. 4i–l).
            history
                .gram_conds
                .push(packed_gram_cond(&buf, sb, inv_n, lam, &mut gram_scaled));
        }

        // Replicated inner solve (eq. 8).
        overlap_tensor_into(&blocks, &mut overlap);
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                w_blocks[j * b + i] = w[row];
            }
        }
        let (g_buf, r_buf) = buf.split_at(gl);
        let deltas =
            backend.ca_inner_solve(s, b, g_buf, r_buf, &w_blocks, &overlap, lam, inv_n)?;

        // Deferred updates (eqs. 9–10).
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                w[row] += deltas[j * b + i];
            }
        }
        backend.alpha_update(a_loc, &idx_flat, &deltas, &mut alpha_loc)?;

        let h_now = (k + 1) * s;
        history.iters = h_now;
        if should_record(h_now, s, opts) || k + 1 == outer {
            record(
                &mut history,
                h_now,
                &w,
                &alpha_loc,
                y_loc,
                n_global,
                lam,
                reference,
                comm,
            )?;
            if let (Some(tol), Some(_)) = (opts.tol, reference) {
                if history.final_obj_err() <= tol {
                    break 'outer_loop;
                }
            }
        }
    }

    history.meter = *comm.meter();
    Ok(PrimalOutput {
        w,
        alpha_loc,
        history,
    })
}

/// Software-pipelined variant (`opts.overlap`): the `[G_k | r_k]` buffer
/// reduces through `iallreduce_start`/`iallreduce_wait` while this rank
/// computes `G_{k+1}` and the overlap tensor. One collective per outer
/// iteration, bitwise-identical trajectory to the blocking path.
#[allow(clippy::too_many_arguments)]
fn run_overlapped<C: Communicator>(
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<PrimalOutput> {
    let d = a_loc.rows();
    let n_loc = a_loc.cols();
    opts.validate(d)?;
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let gl = packed_len(sb);
    let inv_n = 1.0 / n_global as f64;
    let lam = opts.lam;

    let mut w = vec![0.0; d];
    let mut alpha_loc = vec![0.0; n_loc];
    let mut history = History::default();

    let mut z = vec![0.0; n_loc];
    let mut w_blocks = vec![0.0; sb];
    let mut gram_scaled = vec![0.0; sb * sb];
    // Ping-pong index sets: `idx_cur` feeds this iteration's residual and
    // α update, `idx_next` the prefetched Gram.
    let mut idx_cur = vec![0usize; sb];
    let mut idx_next = vec![0usize; sb];
    let mut overlap = vec![0.0; s * s * b * b];

    let mut sampler = BlockSampler::new(d, opts.seed);

    record(
        &mut history,
        0,
        &w,
        &alpha_loc,
        y_loc,
        n_global,
        lam,
        reference,
        comm,
    )?;

    let outer = opts.outer_iters();
    let stride = cond_stride(sb, outer);

    // Pipeline prologue: G_0 is computed before the loop; thereafter
    // G_{k+1} is computed under the in-flight reduction of [G_k | r_k].
    let mut blocks: Vec<Vec<usize>> = Vec::new();
    let mut next_buf: Vec<f64> = Vec::new();
    if outer > 0 {
        blocks = sampler.draw_blocks(s, b);
        flatten_blocks(&blocks, b, &mut idx_cur);
        next_buf = comm.take_buf(gl + sb);
        backend.gram_only(a_loc, &idx_cur, &mut next_buf[..gl])?;
    }
    'outer_loop: for k in 0..outer {
        let mut buf = std::mem::take(&mut next_buf); // holds G_k (packed)

        // z = y − α (local slice), then r_k into the buffer tail.
        for ((zi, yi), ai) in z.iter_mut().zip(y_loc).zip(&alpha_loc) {
            *zi = yi - ai;
        }
        backend.resid_only(a_loc, &idx_cur, &z, &mut buf[gl..])?;

        // THE communication of this outer iteration — non-blocking.
        let handle = comm.iallreduce_start(buf)?;

        // ---- local work hidden behind the in-flight reduction -----------
        let mut pending_blocks: Option<Vec<Vec<usize>>> = None;
        if k + 1 < outer {
            let nb = sampler.draw_blocks(s, b);
            flatten_blocks(&nb, b, &mut idx_next);
            next_buf = comm.take_buf(gl + sb);
            backend.gram_only(a_loc, &idx_next, &mut next_buf[..gl])?;
            pending_blocks = Some(nb);
        }
        overlap_tensor_into(&blocks, &mut overlap);
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                w_blocks[j * b + i] = w[row];
            }
        }
        // ------------------------------------------------------------------
        let buf = comm.iallreduce_wait(handle)?;

        if opts.track_gram_cond && k % stride == 0 {
            history
                .gram_conds
                .push(packed_gram_cond(&buf, sb, inv_n, lam, &mut gram_scaled));
        }

        // Replicated inner solve (eq. 8) and deferred updates (eqs. 9–10).
        let (g_buf, r_buf) = buf.split_at(gl);
        let deltas =
            backend.ca_inner_solve(s, b, g_buf, r_buf, &w_blocks, &overlap, lam, inv_n)?;
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                w[row] += deltas[j * b + i];
            }
        }
        backend.alpha_update(a_loc, &idx_cur, &deltas, &mut alpha_loc)?;
        comm.give_buf(buf);

        // Rotate the pipeline.
        if let Some(nb) = pending_blocks {
            blocks = nb;
            std::mem::swap(&mut idx_cur, &mut idx_next);
        }

        let h_now = (k + 1) * s;
        history.iters = h_now;
        if should_record(h_now, s, opts) || k + 1 == outer {
            record(
                &mut history,
                h_now,
                &w,
                &alpha_loc,
                y_loc,
                n_global,
                lam,
                reference,
                comm,
            )?;
            if let (Some(tol), Some(_)) = (opts.tol, reference) {
                if history.final_obj_err() <= tol {
                    break 'outer_loop;
                }
            }
        }
    }
    if !next_buf.is_empty() {
        // Early stop left a prefetched Gram in flight-side storage.
        comm.give_buf(next_buf);
    }

    history.meter = *comm.meter();
    Ok(PrimalOutput {
        w,
        alpha_loc,
        history,
    })
}

/// Meter-excluded metric evaluation: objective needs one scalar allreduce
/// (‖α−y‖² is distributed), solution error is rank-local (w replicated).
#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w: &[f64],
    alpha_loc: &[f64],
    y_loc: &[f64],
    n_global: usize,
    lam: f64,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<()> {
    let Some(r) = reference else { return Ok(()) };
    let resid_sq = metered_out(comm, |c| {
        let mut part = [alpha_loc
            .iter()
            .zip(y_loc)
            .map(|(a, y)| (a - y) * (a - y))
            .sum::<f64>()];
        c.allreduce_sum(&mut part)?;
        Ok(part[0])
    })?;
    let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
    let f_alg = objective_value(resid_sq, w_norm_sq, n_global, lam);
    history.records.push(IterRecord {
        iter,
        obj_err: relative_objective_error(f_alg, r.f_opt),
        sol_err: relative_solution_error(w, &r.w_opt),
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::matrix::{DenseMatrix, Matrix};

    fn toy() -> (Matrix, Vec<f64>) {
        // 6 features × 40 points, well-conditioned.
        let mut data = vec![0.0; 6 * 40];
        let mut state = 77u64;
        for v in data.iter_mut() {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = (state as f64 / u64::MAX as f64) - 0.5;
        }
        let x = Matrix::Dense(DenseMatrix::from_vec(6, 40, data));
        let mut y = vec![0.0; 40];
        x.matvec_t(&[1.0; 6], &mut y).unwrap();
        (x, y)
    }

    fn solve_direct(x: &Matrix, y: &[f64], lam: f64) -> Vec<f64> {
        // (XXᵀ/n + λI) w = Xy/n via dense Cholesky.
        let d = x.rows();
        let n = x.cols();
        let idx: Vec<usize> = (0..d).collect();
        let mut g = vec![0.0; d * d];
        x.sampled_gram(&idx, &mut g).unwrap();
        for i in 0..d {
            for j in 0..d {
                g[i * d + j] /= n as f64;
            }
            g[i * d + i] += lam;
        }
        let mut rhs = vec![0.0; d];
        x.matvec(y, &mut rhs).unwrap();
        for v in rhs.iter_mut() {
            *v /= n as f64;
        }
        crate::linalg::chol_solve(&g, d, &mut rhs).unwrap();
        rhs
    }

    #[test]
    fn bcd_converges_to_ridge_solution() {
        let (x, y) = toy();
        let lam = 0.05;
        let w_opt = solve_direct(&x, &y, lam);
        let opts = SolverOpts {
            b: 3,
            s: 1,
            lam,
            iters: 4000,
            seed: 1,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let out = run(&x, &y, 40, &opts, None, &mut comm, &mut be).unwrap();
        let err = relative_solution_error(&out.w, &w_opt);
        assert!(err < 1e-8, "solution error {err}");
    }

    #[test]
    fn ca_bcd_matches_bcd_trajectory() {
        // The paper's exact-arithmetic equivalence claim, at fp tolerance.
        let (x, y) = toy();
        let lam = 0.05;
        let base_opts = SolverOpts {
            b: 2,
            s: 1,
            lam,
            iters: 60,
            seed: 9,
            record_every: 0,
            ..Default::default()
        };
        let mut ca_opts = base_opts.clone();
        ca_opts.s = 5;
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&x, &y, 40, &base_opts, None, &mut comm, &mut be)
            .unwrap()
            .w;
        let w2 = run(&x, &y, 40, &ca_opts, None, &mut comm, &mut be)
            .unwrap()
            .w;
        for (a, b) in w1.iter().zip(&w2) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
    }

    #[test]
    fn overlap_mode_is_bitwise_identical_serial() {
        let (x, y) = toy();
        let mut opts = SolverOpts {
            b: 2,
            s: 3,
            lam: 0.05,
            iters: 30,
            seed: 4,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&x, &y, 40, &opts, None, &mut comm, &mut be).unwrap().w;
        opts.overlap = true;
        let out2 = run(&x, &y, 40, &opts, None, &mut comm, &mut be).unwrap();
        assert_eq!(w1, out2.w, "overlap pipeline changed the trajectory");
    }

    #[test]
    fn allreduce_count_drops_by_s() {
        let (x, y) = toy();
        let mk = |s: usize| SolverOpts {
            b: 2,
            s,
            lam: 0.05,
            iters: 60,
            seed: 3,
            record_every: 0,
            ..Default::default()
        };
        let mut be = NativeBackend::new();
        let mut c1 = SerialComm::new();
        let h1 = run(&x, &y, 40, &mk(1), None, &mut c1, &mut be)
            .unwrap()
            .history;
        let mut c5 = SerialComm::new();
        let h5 = run(&x, &y, 40, &mk(5), None, &mut c5, &mut be)
            .unwrap()
            .history;
        assert_eq!(h1.meter.allreduces, 60);
        assert_eq!(h5.meter.allreduces, 12);
    }
}
