//! Primal block coordinate descent — Algorithm 1 (`s = 1`) and its
//! communication-avoiding unrolling, Algorithm 2 (`s > 1`).
//!
//! SPMD over a 1D-block-column partition of `X ∈ R^{d×n}`: each rank holds
//! `X_loc = X[:, lo..hi]`, the matching slices of `y` and `α = Xᵀw`, and a
//! full replica of `w`. One outer iteration:
//!
//! 1. every rank draws the same `s` size-`b` row blocks (shared seed — no
//!    communication),
//! 2. computes its raw partial `G = Y_loc Y_locᵀ` (packed lower triangle),
//!    `r = Y_loc (y−α)_loc` through the pluggable [`ComputeBackend`]
//!    (native Rust or the AOT Pallas artifact via PJRT),
//! 3. **one allreduce** of the `(sb(sb+1)/2 + sb)`-word packed `[G|r]`
//!    buffer — the only communication of the outer iteration, giving the
//!    Θ(s) latency saving (G is symmetric, so only its triangle rides the
//!    wire; the inner solve indexes the triangle directly),
//! 4. solves the `s` deferred `b×b` subproblems redundantly (eq. 8),
//! 5. applies the deferred updates: `w[I_t] += Δ_t`, `α_loc += Y_locᵀ δ`.
//!
//! The loop itself lives in the shared pipeline core
//! ([`crate::engine::drive`]); this module contributes only the
//! method-specific callbacks ([`BcdStep`]). With
//! [`SolverOpts::overlap`] the engine's prefetch schedule software-
//! pipelines the iteration: the `[G_k | r_k]` buffer reduces through the
//! non-blocking allreduce while the rank computes `G_{k+1}` (legal
//! because G depends only on X and the shared-seed sample stream, never
//! on the evolving α/w state) and assembles the overlap tensor. Still
//! exactly one collective per outer iteration, same payload, same
//! reduction algorithm — the trajectory is **bitwise identical** to the
//! blocking path (asserted against the frozen pre-engine loops in
//! `rust/tests/engine_equivalence.rs`).

use crate::comm::Communicator;
use crate::engine::{drive, CaStep, Checkpoint, Method, Problem, Sample, Session};
use crate::error::Result;
use crate::gram::ComputeBackend;
use crate::linalg::packed::packed_len;
use crate::matrix::Matrix;
use crate::metrics::{
    relative_objective_error, relative_solution_error, History, IterRecord, Reference,
};
use crate::sampling::{overlap_tensor_into, BlockSampler};
use crate::solvers::common::{metered_out, objective_value, PrimalOutput, SolverOpts};

/// Run BCD / CA-BCD on this rank's shard.
///
/// Thin wrapper over the engine's single entry point — equivalent to
/// `Session::new(&Problem::primal(…)).opts(…).method(Method::CaBcd)…`;
/// kept so existing callers (and the paper-numbered docs above) have a
/// stable address. Non-L2 regularizers route through the CA-Prox loop
/// (same packed `[G|r]` payload and H/s collectives; `reference` does not
/// apply there and a warning is emitted if one is supplied).
///
/// * `a_loc` — `d × n_loc` local column block of X.
/// * `y_loc` — local slice of the labels.
/// * `n_global` — total number of data points n.
/// * `reference` — optional `w_opt` ground truth for error recording.
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<PrimalOutput> {
    let problem = Problem::primal(a_loc, y_loc, n_global).with_reference(reference);
    Session::new(&problem)
        .opts(opts.clone())
        .method(Method::CaBcd)
        .backend(backend)
        .comm(comm)
        .run()?
        .into_primal()
}

/// Engine entry point: build the [`BcdStep`], drive it through the shared
/// pipeline, and assemble the output. Called by
/// [`Session::run`](crate::engine::Session::run).
pub(crate) fn engine_run<C: Communicator>(
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    opts: &SolverOpts,
    reference: Option<&Reference>,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<PrimalOutput> {
    let d = a_loc.rows();
    let n_loc = a_loc.cols();
    opts.validate(d)?;
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let mut history = History::default();
    let mut step = BcdStep {
        a_loc,
        y_loc,
        n_global,
        reference,
        backend,
        s,
        b,
        lam: opts.lam,
        inv_n: 1.0 / n_global as f64,
        gl: packed_len(sb),
        sampler: BlockSampler::new(d, opts.seed),
        w: vec![0.0; d],
        alpha_loc: vec![0.0; n_loc],
        z: vec![0.0; n_loc],
        w_blocks: vec![0.0; sb],
        overlap: vec![0.0; s * s * b * b],
    };
    drive(&mut step, opts, comm, &mut history)?;
    Ok(PrimalOutput {
        w: step.w,
        alpha_loc: step.alpha_loc,
        history,
    })
}

/// The matched-layout primal method's per-iteration callbacks (see the
/// module docs for the algorithm and [`CaStep`] for the schedule
/// contract). Scratch buffers are hoisted into the struct once; the only
/// per-iteration heap traffic is the engine-owned payload buffers (pooled
/// in overlap mode) and the [`Sample`]'s block/index lists — the same
/// small vectors `BlockSampler::draw_blocks` always allocated per outer
/// iteration in the pre-engine loops.
pub(crate) struct BcdStep<'a> {
    a_loc: &'a Matrix,
    y_loc: &'a [f64],
    n_global: usize,
    reference: Option<&'a Reference>,
    backend: &'a mut dyn ComputeBackend,
    s: usize,
    b: usize,
    lam: f64,
    inv_n: f64,
    gl: usize,
    sampler: BlockSampler,
    /// Replicated primal iterate.
    w: Vec<f64>,
    /// This rank's slice of α = Xᵀw.
    alpha_loc: Vec<f64>,
    z: Vec<f64>,
    w_blocks: Vec<f64>,
    overlap: Vec<f64>,
}

impl<C: Communicator> CaStep<C> for BcdStep<'_> {
    fn payload_split(&self) -> (usize, usize) {
        (self.gl, self.s * self.b)
    }

    fn prefetch_gram(&self) -> bool {
        true
    }

    fn sample(&mut self, _comm: &mut C, k: usize) -> Result<Sample> {
        Ok(Sample::flatten(
            k,
            self.sampler.draw_blocks(self.s, self.b),
            self.b,
        ))
    }

    fn local_gram(&mut self, _comm: &mut C, smp: &Sample, head: &mut [f64]) -> Result<()> {
        self.backend.gram_only(self.a_loc, &smp.idx, head)
    }

    fn local_state(&mut self, smp: &Sample, tail: &mut [f64]) -> Result<()> {
        // z = y − α (local slice), then r = Y_loc·z into the payload tail.
        for ((zi, yi), ai) in self.z.iter_mut().zip(self.y_loc).zip(&self.alpha_loc) {
            *zi = yi - ai;
        }
        self.backend.resid_only(self.a_loc, &smp.idx, &self.z, tail)
    }

    fn local_payload(
        &mut self,
        _comm: &mut C,
        smp: &Sample,
        head: &mut [f64],
        tail: &mut [f64],
    ) -> Result<()> {
        // Same-iteration gram + residual: use the fused kernel (one
        // backend call — one AOT artifact execution on the XLA path),
        // exactly like the pre-engine blocking loop.
        for ((zi, yi), ai) in self.z.iter_mut().zip(self.y_loc).zip(&self.alpha_loc) {
            *zi = yi - ai;
        }
        self.backend
            .gram_resid(self.a_loc, &smp.idx, &self.z, head, tail)
    }

    fn hidden_work(&mut self, smp: &Sample) -> Result<()> {
        overlap_tensor_into(&smp.blocks, &mut self.overlap);
        for (j, blk) in smp.blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                self.w_blocks[j * self.b + i] = self.w[row];
            }
        }
        Ok(())
    }

    fn cond_probe(&self) -> Option<(f64, f64)> {
        // Condition number of G = (1/n)·YYᵀ + λI (paper Figs. 4i–l).
        Some((self.inv_n, self.lam))
    }

    fn inner_solve(&mut self, _smp: &Sample, head: &[f64], tail: &[f64]) -> Result<Vec<f64>> {
        // Replicated inner solve (eq. 8).
        self.backend.ca_inner_solve(
            self.s,
            self.b,
            head,
            tail,
            &self.w_blocks,
            &self.overlap,
            self.lam,
            self.inv_n,
        )
    }

    fn apply(&mut self, smp: &Sample, deltas: &[f64]) -> Result<()> {
        // Deferred updates (eqs. 9–10).
        for (j, blk) in smp.blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                self.w[row] += deltas[j * self.b + i];
            }
        }
        self.backend
            .alpha_update(self.a_loc, &smp.idx, deltas, &mut self.alpha_loc)
    }

    fn record(&mut self, comm: &mut C, history: &mut History, h_now: usize) -> Result<()> {
        record(
            history,
            h_now,
            &self.w,
            &self.alpha_loc,
            self.y_loc,
            self.n_global,
            self.lam,
            self.reference,
            comm,
        )
    }

    fn converged(&self, history: &History, tol: f64) -> bool {
        self.reference.is_some() && history.final_obj_err() <= tol
    }

    fn ckpt_kind(&self) -> &'static str {
        "bcd"
    }

    fn save_state(&self, ckpt: &mut Checkpoint) -> Result<()> {
        // Full mutable state: sampler RNG + the two iterates. z /
        // w_blocks / overlap are scratch, refilled before every use.
        ckpt.rng = self.sampler.rng_state().to_vec();
        ckpt.push_f64("w", &self.w);
        ckpt.push_f64("alpha_loc", &self.alpha_loc);
        Ok(())
    }

    fn restore_state(&mut self, ckpt: &Checkpoint) -> Result<()> {
        self.sampler.set_rng_state(ckpt.rng_words()?);
        ckpt.read_f64_into("w", &mut self.w)?;
        ckpt.read_f64_into("alpha_loc", &mut self.alpha_loc)
    }
}

/// Meter-excluded metric evaluation: objective needs one scalar allreduce
/// (‖α−y‖² is distributed), solution error is rank-local (w replicated).
#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w: &[f64],
    alpha_loc: &[f64],
    y_loc: &[f64],
    n_global: usize,
    lam: f64,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<()> {
    let Some(r) = reference else { return Ok(()) };
    let resid_sq = metered_out(comm, |c| {
        let mut part = [alpha_loc
            .iter()
            .zip(y_loc)
            .map(|(a, y)| (a - y) * (a - y))
            .sum::<f64>()];
        c.allreduce_sum(&mut part)?;
        Ok(part[0])
    })?;
    let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
    let f_alg = objective_value(resid_sq, w_norm_sq, n_global, lam);
    history.records.push(IterRecord {
        iter,
        obj_err: relative_objective_error(f_alg, r.f_opt),
        sol_err: relative_solution_error(w, &r.w_opt),
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::matrix::{DenseMatrix, Matrix};

    fn toy() -> (Matrix, Vec<f64>) {
        // 6 features × 40 points, well-conditioned.
        let mut data = vec![0.0; 6 * 40];
        let mut state = 77u64;
        for v in data.iter_mut() {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = (state as f64 / u64::MAX as f64) - 0.5;
        }
        let x = Matrix::Dense(DenseMatrix::from_vec(6, 40, data));
        let mut y = vec![0.0; 40];
        x.matvec_t(&[1.0; 6], &mut y).unwrap();
        (x, y)
    }

    fn solve_direct(x: &Matrix, y: &[f64], lam: f64) -> Vec<f64> {
        // (XXᵀ/n + λI) w = Xy/n via dense Cholesky.
        let d = x.rows();
        let n = x.cols();
        let idx: Vec<usize> = (0..d).collect();
        let mut g = vec![0.0; d * d];
        x.sampled_gram(&idx, &mut g).unwrap();
        for i in 0..d {
            for j in 0..d {
                g[i * d + j] /= n as f64;
            }
            g[i * d + i] += lam;
        }
        let mut rhs = vec![0.0; d];
        x.matvec(y, &mut rhs).unwrap();
        for v in rhs.iter_mut() {
            *v /= n as f64;
        }
        crate::linalg::chol_solve(&g, d, &mut rhs).unwrap();
        rhs
    }

    #[test]
    fn bcd_converges_to_ridge_solution() {
        let (x, y) = toy();
        let lam = 0.05;
        let w_opt = solve_direct(&x, &y, lam);
        let opts = SolverOpts {
            b: 3,
            s: 1,
            lam,
            iters: 4000,
            seed: 1,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let out = run(&x, &y, 40, &opts, None, &mut comm, &mut be).unwrap();
        let err = relative_solution_error(&out.w, &w_opt);
        assert!(err < 1e-8, "solution error {err}");
    }

    #[test]
    fn ca_bcd_matches_bcd_trajectory() {
        // The paper's exact-arithmetic equivalence claim, at fp tolerance.
        let (x, y) = toy();
        let lam = 0.05;
        let base_opts = SolverOpts {
            b: 2,
            s: 1,
            lam,
            iters: 60,
            seed: 9,
            record_every: 0,
            ..Default::default()
        };
        let mut ca_opts = base_opts.clone();
        ca_opts.s = 5;
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&x, &y, 40, &base_opts, None, &mut comm, &mut be)
            .unwrap()
            .w;
        let w2 = run(&x, &y, 40, &ca_opts, None, &mut comm, &mut be)
            .unwrap()
            .w;
        for (a, b) in w1.iter().zip(&w2) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
    }

    #[test]
    fn overlap_mode_is_bitwise_identical_serial() {
        let (x, y) = toy();
        let mut opts = SolverOpts {
            b: 2,
            s: 3,
            lam: 0.05,
            iters: 30,
            seed: 4,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&x, &y, 40, &opts, None, &mut comm, &mut be).unwrap().w;
        opts.overlap = true;
        let out2 = run(&x, &y, 40, &opts, None, &mut comm, &mut be).unwrap();
        assert_eq!(w1, out2.w, "overlap pipeline changed the trajectory");
    }

    #[test]
    fn allreduce_count_drops_by_s() {
        let (x, y) = toy();
        let mk = |s: usize| SolverOpts {
            b: 2,
            s,
            lam: 0.05,
            iters: 60,
            seed: 3,
            record_every: 0,
            ..Default::default()
        };
        let mut be = NativeBackend::new();
        let mut c1 = SerialComm::new();
        let h1 = run(&x, &y, 40, &mk(1), None, &mut c1, &mut be)
            .unwrap()
            .history;
        let mut c5 = SerialComm::new();
        let h5 = run(&x, &y, 40, &mk(5), None, &mut c5, &mut be)
            .unwrap()
            .history;
        assert_eq!(h1.meter.allreduces, 60);
        assert_eq!(h5.meter.allreduces, 12);
    }
}
