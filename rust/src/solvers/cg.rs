//! Distributed conjugate gradients on the regularized normal equations
//! `(XXᵀ/n + λI)·w = X·y/n` — the paper's Krylov baseline (Table 2,
//! Figure 1) and its ground-truth source (`w_opt` at tol 1e-15, §5.1).
//!
//! 1D-block-column layout: every d-vector is replicated, every n-vector is
//! partitioned. One iteration costs exactly one allreduce (the matvec
//! partial sum — inner products of replicated vectors are rank-local),
//! matching the paper's "CG communicates a single vector per iteration".

use crate::comm::Communicator;
use crate::error::Result;
use crate::matrix::Matrix;
use crate::metrics::{
    relative_objective_error, relative_solution_error, History, IterRecord, Reference,
};
use crate::solvers::common::{metered_out, objective_value};

/// CG options.
#[derive(Clone, Debug)]
pub struct CgOpts {
    /// Regularization λ.
    pub lam: f64,
    /// Iteration cap.
    pub max_iters: usize,
    /// Stop when ‖residual‖/‖rhs‖ ≤ tol.
    pub tol: f64,
    /// Record convergence metrics every this many iterations (0 = ends).
    pub record_every: usize,
}

impl Default for CgOpts {
    fn default() -> Self {
        CgOpts {
            lam: 1e-3,
            max_iters: 1000,
            tol: 1e-12,
            record_every: 0,
        }
    }
}

/// CG output: replicated solution + iteration count + trajectory.
#[derive(Clone, Debug)]
pub struct CgOutput {
    /// Replicated CG solution.
    pub w: Vec<f64>,
    /// Iterations executed before the residual tolerance was met.
    pub iters: usize,
    /// Trajectory + communication accounting of the run.
    pub history: History,
}

/// Distributed matvec `u = (X_loc·X_locᵀ v)` partial, allreduced, then
/// scaled: `u = XXᵀv/n + λv`.
fn apply<C: Communicator>(
    a_loc: &Matrix,
    v: &[f64],
    lam: f64,
    n: usize,
    tmp_n: &mut [f64],
    out: &mut Vec<f64>,
    comm: &mut C,
) -> Result<()> {
    a_loc.matvec_t(v, tmp_n)?;
    a_loc.matvec(tmp_n, out)?;
    comm.allreduce_sum(out)?;
    let inv_n = 1.0 / n as f64;
    for (o, &vi) in out.iter_mut().zip(v) {
        *o = *o * inv_n + lam * vi;
    }
    Ok(())
}

/// Run CG on this rank's column shard of X.
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    opts: &CgOpts,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<CgOutput> {
    let d = a_loc.rows();
    let n_loc = a_loc.cols();
    let mut history = History::default();

    // rhs = X y / n (one allreduce).
    let mut rhs = vec![0.0; d];
    a_loc.matvec(y_loc, &mut rhs)?;
    comm.allreduce_sum(&mut rhs)?;
    let inv_n = 1.0 / n_global as f64;
    for v in rhs.iter_mut() {
        *v *= inv_n;
    }
    let rhs_norm = rhs.iter().map(|v| v * v).sum::<f64>().sqrt();

    let mut w = vec![0.0; d];
    let mut r = rhs.clone();
    let mut p = r.clone();
    let mut ap = vec![0.0; d];
    let mut tmp_n = vec![0.0; n_loc];
    let mut rs_old: f64 = r.iter().map(|v| v * v).sum();

    record(&mut history, 0, &w, a_loc, y_loc, n_global, opts.lam, reference, comm)?;

    let mut iters = 0;
    for it in 1..=opts.max_iters {
        iters = it;
        apply(a_loc, &p, opts.lam, n_global, &mut tmp_n, &mut ap, comm)?;
        let pap: f64 = p.iter().zip(&ap).map(|(a, b)| a * b).sum();
        if pap <= 0.0 {
            break; // numerically singular direction — SPD exhausted
        }
        let alpha = rs_old / pap;
        for i in 0..d {
            w[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new: f64 = r.iter().map(|v| v * v).sum();
        if opts.record_every > 0 && it % opts.record_every == 0 {
            record(&mut history, it, &w, a_loc, y_loc, n_global, opts.lam, reference, comm)?;
        }
        if rs_new.sqrt() <= opts.tol * rhs_norm.max(1e-300) {
            break;
        }
        let beta = rs_new / rs_old;
        for i in 0..d {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }
    record(&mut history, iters, &w, a_loc, y_loc, n_global, opts.lam, reference, comm)?;
    history.iters = iters;
    history.meter = *comm.meter();
    Ok(CgOutput { w, iters, history })
}

#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w: &[f64],
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    lam: f64,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<()> {
    let Some(rf) = reference else { return Ok(()) };
    let resid_sq = metered_out(comm, |c| {
        let n_loc = a_loc.cols();
        let mut xtw = vec![0.0; n_loc];
        a_loc.matvec_t(w, &mut xtw)?;
        let mut part = [xtw
            .iter()
            .zip(y_loc)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()];
        c.allreduce_sum(&mut part)?;
        Ok(part[0])
    })?;
    let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
    let f_alg = objective_value(resid_sq, w_norm_sq, n_global, lam);
    history.records.push(IterRecord {
        iter,
        obj_err: relative_objective_error(f_alg, rf.f_opt),
        sol_err: relative_solution_error(w, &rf.w_opt),
    });
    Ok(())
}

/// Compute the paper's ground truth on this rank: CG at tol 1e-15, plus the
/// optimum's objective value.
pub fn compute_reference<C: Communicator>(
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    lam: f64,
    comm: &mut C,
) -> Result<Reference> {
    let opts = CgOpts {
        lam,
        max_iters: 50_000,
        tol: 1e-15,
        record_every: 0,
    };
    let out = metered_out(comm, |c| run(a_loc, y_loc, n_global, &opts, None, c))?;
    // f_opt — one scalar allreduce.
    let resid_sq = metered_out(comm, |c| {
        let mut xtw = vec![0.0; a_loc.cols()];
        a_loc.matvec_t(&out.w, &mut xtw)?;
        let mut part = [xtw
            .iter()
            .zip(y_loc)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()];
        c.allreduce_sum(&mut part)?;
        Ok(part[0])
    })?;
    let w_norm_sq: f64 = out.w.iter().map(|v| v * v).sum();
    Ok(Reference {
        f_opt: objective_value(resid_sq, w_norm_sq, n_global, lam),
        w_opt: out.w,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::matrix::{DenseMatrix, Matrix};

    fn toy() -> (Matrix, Vec<f64>) {
        let mut data = vec![0.0; 8 * 50];
        let mut state = 5u64;
        for v in data.iter_mut() {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = (state as f64 / u64::MAX as f64) - 0.5;
        }
        let x = Matrix::Dense(DenseMatrix::from_vec(8, 50, data));
        let mut y = vec![0.0; 50];
        x.matvec_t(&[1.0; 8], &mut y).unwrap();
        (x, y)
    }

    #[test]
    fn cg_solves_normal_equations() {
        let (x, y) = toy();
        let lam = 0.01;
        let mut comm = SerialComm::new();
        let out = run(
            &x,
            &y,
            50,
            &CgOpts {
                lam,
                max_iters: 500,
                tol: 1e-14,
                record_every: 0,
            },
            None,
            &mut comm,
        )
        .unwrap();
        // Verify gradient ≈ 0: (XXᵀ/n + λI)w − Xy/n.
        let n = 50.0;
        let mut xtw = vec![0.0; 50];
        x.matvec_t(&out.w, &mut xtw).unwrap();
        let mut xxw = vec![0.0; 8];
        x.matvec(&xtw, &mut xxw).unwrap();
        let mut xy = vec![0.0; 8];
        x.matvec(&y, &mut xy).unwrap();
        for i in 0..8 {
            let g = xxw[i] / n + lam * out.w[i] - xy[i] / n;
            assert!(g.abs() < 1e-10, "grad {i}: {g}");
        }
        assert!(out.iters <= 9, "CG on 8-dim SPD should finish fast");
    }

    #[test]
    fn reference_is_optimum() {
        let (x, y) = toy();
        let mut comm = SerialComm::new();
        let rf = compute_reference(&x, &y, 50, 0.01, &mut comm).unwrap();
        assert_eq!(rf.w_opt.len(), 8);
        assert!(rf.f_opt > 0.0);
        // Meter unpolluted by reference computation.
        assert_eq!(comm.meter().allreduces, 0);
    }
}
