//! TSQR direct least-squares baseline at the solver interface.
//!
//! Wraps [`crate::linalg::tsqr`] for the §2.1 survey comparison (Figure 1,
//! Table 2). TSQR is a single-pass direct method: its "convergence curve"
//! is flat until the one reduction completes, then drops to machine
//! precision — we report exactly that shape, plus the real solve.
//!
//! The in-process tree (P leaf blocks, ⌈log₂P⌉ combine levels) is executed
//! for real; the distributed cost is charged by the cost model
//! ([`crate::costmodel::theory::Method::Tsqr`]). Only sensible for moderate
//! d — exactly the regime the paper runs it in.

use crate::error::Result;
use crate::linalg::tsqr::tsqr_solve_ls;
use crate::matrix::Matrix;
use crate::metrics::{relative_objective_error, relative_solution_error, History, IterRecord, Reference};
use crate::solvers::common::objective_value;

/// Output of the TSQR baseline.
#[derive(Clone, Debug)]
pub struct TsqrOutput {
    /// The direct least-squares solution.
    pub w: Vec<f64>,
    /// Tree combine levels executed (= the single-allreduce latency).
    pub combine_levels: usize,
    /// The single-pass "trajectory" (flat, then machine precision).
    pub history: History,
}

/// Solve the regularized LS problem directly over `p_blocks` leaf blocks.
pub fn run(
    x: &Matrix,
    y: &[f64],
    lam: f64,
    p_blocks: usize,
    reference: Option<&Reference>,
) -> Result<TsqrOutput> {
    let n = x.cols();
    let (w, combine_levels) = tsqr_solve_ls(x, y, lam, p_blocks)?;
    let mut history = History::default();
    if let Some(rf) = reference {
        let mut xtw = vec![0.0; n];
        x.matvec_t(&w, &mut xtw)?;
        let resid_sq: f64 = xtw.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
        let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
        let f_alg = objective_value(resid_sq, w_norm_sq, n, lam);
        // Single-pass: error is "1" until the pass completes, then done.
        history.records.push(IterRecord {
            iter: 0,
            obj_err: 1.0,
            sol_err: 1.0,
        });
        history.records.push(IterRecord {
            iter: 1,
            obj_err: relative_objective_error(f_alg, rf.f_opt),
            sol_err: relative_solution_error(&w, &rf.w_opt),
        });
    }
    history.iters = 1;
    Ok(TsqrOutput {
        w,
        combine_levels,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::matrix::{DenseMatrix, Matrix};
    use crate::solvers::cg;

    #[test]
    fn tsqr_matches_cg_reference() {
        let mut data = vec![0.0; 7 * 60];
        let mut state = 31u64;
        for v in data.iter_mut() {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = (state as f64 / u64::MAX as f64) - 0.5;
        }
        let x = Matrix::Dense(DenseMatrix::from_vec(7, 60, data));
        let mut y = vec![0.0; 60];
        x.matvec_t(&[2.0; 7], &mut y).unwrap();
        let lam = 0.05;
        let mut comm = SerialComm::new();
        let rf = cg::compute_reference(&x, &y, 60, lam, &mut comm).unwrap();
        let out = run(&x, &y, lam, 8, Some(&rf)).unwrap();
        // Direct solve hits machine precision in one pass.
        let final_err = out.history.records.last().unwrap().sol_err;
        assert!(final_err < 1e-10, "sol err {final_err}");
        assert!(out.combine_levels >= 3);
    }
}
