//! CoCoA-style communication-efficient baseline (Jaggi et al. [24]) — the
//! framework the paper contrasts against in §1: it reduces communication by
//! running dual coordinate descent on *locally stored* data points and
//! intermittently averaging, but — unlike the CA transformation — it
//! **changes the convergence behaviour** (and communicates fewer times only
//! heuristically). This implementation exists to demonstrate exactly that
//! contrast (see the `ablation_cocoa` bench and the trajectory test below).
//!
//! One round: every rank performs `local_iters` single-coordinate dual
//! updates (SDCA with least-squares loss, b′=1) over its own data points
//! against a stale local copy of w, then the Δw contributions are averaged
//! (γ = 1/P, the safe CoCoA combiner) with ONE allreduce.
//!
//! Note on the packed-Gram wire format used by the CA solvers: CoCoA has
//! no `[G|r]` payload to pack — its one collective per round is the
//! length-`d` Δw combine, already minimal (exactly `d` words/rank/round;
//! asserted alongside the packed-payload word counts in
//! `tests/packed_gram.rs`).

use crate::comm::Communicator;
use crate::error::Result;
use crate::matrix::Matrix;
use crate::metrics::{relative_objective_error, relative_solution_error, History, IterRecord,
    Reference};
use crate::sampling::BlockSampler;
use crate::solvers::common::{metered_out, objective_value};

/// CoCoA options.
#[derive(Clone, Debug)]
pub struct CocoaOpts {
    pub lam: f64,
    /// Outer (communication) rounds.
    pub rounds: usize,
    /// Local dual coordinate updates per round.
    pub local_iters: usize,
    pub seed: u64,
    pub record_every: usize,
    /// Reduce the Δw contribution with the non-blocking allreduce, hiding
    /// it behind the local dual-block commit (which is independent of the
    /// combined Δw). Bitwise identical to the blocking path.
    pub overlap: bool,
}

impl Default for CocoaOpts {
    fn default() -> Self {
        CocoaOpts {
            lam: 1e-3,
            rounds: 100,
            local_iters: 100,
            seed: 0,
            record_every: 0,
            overlap: false,
        }
    }
}

/// Output: replicated w, this rank's dual slice, history.
#[derive(Clone, Debug)]
pub struct CocoaOutput {
    pub w: Vec<f64>,
    pub alpha_loc: Vec<f64>,
    pub history: History,
}

/// Run CoCoA on this rank's 1D-block-column shard of X.
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    opts: &CocoaOpts,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<CocoaOutput> {
    let d = a_loc.rows();
    let n_loc = a_loc.cols();
    let lam = opts.lam;
    let n = n_global as f64;
    let p = comm.size() as f64;

    let mut w = vec![0.0; d];
    let mut alpha_loc = vec![0.0; n_loc];
    let mut history = History::default();
    // Local columns as rows of Aᵀ for cheap column access.
    let at = a_loc.transpose(); // n_loc × d
    // Per-point squared norms ‖x_j‖² (the SDCA denominator).
    let mut col_norms = vec![0.0; n_loc];
    for j in 0..n_loc {
        let mut row = vec![0.0; d];
        at.gather_rows(&[j], &mut row)?;
        col_norms[j] = row.iter().map(|v| v * v).sum();
    }

    // Rank-decorrelated sampling (unlike the CA solvers, CoCoA WANTS each
    // rank to walk its own coordinates).
    let mut sampler = if n_loc > 0 {
        Some(BlockSampler::new(n_loc, opts.seed ^ (comm.rank() as u64) << 32))
    } else {
        None
    };

    record(&mut history, 0, &w, a_loc, y_loc, n_global, lam, reference, comm)?;

    let mut xrow = vec![0.0; d];
    let mut alpha_work = vec![0.0; n_loc];
    for round in 1..=opts.rounds {
        // Local phase: SDCA epochs against a frozen w, on a WORKING copy
        // of the local dual block (committed scaled by γ below — the
        // CoCoA-v1 averaging combiner, which keeps w = −(1/λn)·Xα exact).
        let mut w_local = w.clone();
        let mut dw = vec![0.0; d];
        alpha_work.copy_from_slice(&alpha_loc);
        if let Some(sampler) = sampler.as_mut() {
            for _ in 0..opts.local_iters {
                let j = sampler.draw_block(1)[0];
                at.gather_rows(&[j], &mut xrow)?;
                // Single-coordinate dual step (eq. 17 with b′=1):
                // θ = ‖x_j‖²/(λn²) + 1/n ; Δα = −(1/n)·θ⁻¹(−x_jᵀw + α_j + y_j)
                let theta = col_norms[j] / (lam * n * n) + 1.0 / n;
                let xw: f64 = xrow.iter().zip(&w_local).map(|(a, b)| a * b).sum();
                let rhs = -xw + alpha_work[j] + y_loc[j];
                let da = -(1.0 / n) * rhs / theta;
                alpha_work[j] += da;
                let scale = -da / (lam * n);
                for (t, &xv) in xrow.iter().enumerate() {
                    w_local[t] += scale * xv;
                    dw[t] += scale * xv;
                }
            }
        }
        // Combine with γ = 1/P: α_[k] += γΔα_[k]; w += γ·ΣΔw_k. The
        // averaging preserves the primal-dual coupling but damps every
        // machine's progress — the "changes the convergence behavior"
        // contrast the paper draws against the CA transformation. In
        // overlap mode the local dual-block commit (independent of the
        // combined Δw) hides the in-flight reduction.
        if opts.overlap {
            let handle = comm.iallreduce_start(dw)?;
            for (a, &work) in alpha_loc.iter_mut().zip(&alpha_work) {
                *a += (work - *a) / p;
            }
            let dw = comm.iallreduce_wait(handle)?;
            for (wi, dv) in w.iter_mut().zip(&dw) {
                *wi += dv / p;
            }
            comm.give_buf(dw);
        } else {
            comm.allreduce_sum(&mut dw)?;
            for (wi, dv) in w.iter_mut().zip(&dw) {
                *wi += dv / p;
            }
            for (a, &work) in alpha_loc.iter_mut().zip(&alpha_work) {
                *a += (work - *a) / p;
            }
        }

        if (opts.record_every > 0 && round % opts.record_every == 0) || round == opts.rounds {
            record(&mut history, round, &w, a_loc, y_loc, n_global, lam, reference, comm)?;
        }
        history.iters = round;
    }

    history.meter = *comm.meter();
    Ok(CocoaOutput {
        w,
        alpha_loc,
        history,
    })
}

#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w: &[f64],
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    lam: f64,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<()> {
    let Some(r) = reference else { return Ok(()) };
    let resid_sq = metered_out(comm, |c| {
        let mut xtw = vec![0.0; a_loc.cols()];
        a_loc.matvec_t(w, &mut xtw)?;
        let mut part = [xtw
            .iter()
            .zip(y_loc)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()];
        c.allreduce_sum(&mut part)?;
        Ok(part[0])
    })?;
    let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
    let f_alg = objective_value(resid_sq, w_norm_sq, n_global, lam);
    history.records.push(IterRecord {
        iter,
        obj_err: relative_objective_error(f_alg, r.f_opt),
        sol_err: relative_solution_error(w, &r.w_opt),
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::thread::run_spmd;
    use crate::comm::SerialComm;
    use crate::coordinator::partition_primal;
    use crate::matrix::gen::{generate, scaled_specs};
    use crate::matrix::io::Dataset;
    use crate::solvers::cg;

    fn setup() -> (Dataset, f64, crate::metrics::Reference) {
        let spec = &scaled_specs(8)[0]; // abalone-s8
        let ds = generate(spec, 4).unwrap();
        let lam = spec.lambda();
        let mut comm = SerialComm::new();
        let r = cg::compute_reference(&ds.x, &ds.y, ds.n(), lam, &mut comm).unwrap();
        (ds, lam, r)
    }

    #[test]
    fn cocoa_converges_toward_optimum() {
        let (ds, lam, r) = setup();
        // Overlap mode: exercises the non-blocking Δw reduction SPMD (the
        // trajectory and the one-allreduce-per-round count are unchanged).
        let opts = CocoaOpts {
            lam,
            rounds: 150,
            local_iters: 400,
            seed: 1,
            record_every: 0,
            overlap: true,
        };
        let shards = partition_primal(&ds, 2).unwrap();
        let opts2 = opts.clone();
        let rref = &r;
        let outs = run_spmd(2, move |rank, comm| {
            let sh = &shards[rank];
            run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts2, Some(rref), comm).unwrap()
        });
        let err = outs[0].history.final_sol_err();
        // γ=1/P averaging converges slowly — the paper's point: the
        // communication saving comes WITH a convergence-behaviour change.
        assert!(err < 0.15, "CoCoA made too little progress: {err}");
        // One allreduce per round — the communication-efficiency claim.
        assert_eq!(outs[0].history.meter.allreduces, 150);
    }

    #[test]
    fn cocoa_changes_convergence_with_rank_count_unlike_ca() {
        // The paper's §1 contrast: CoCoA's trajectory DEPENDS on P (local
        // solves + averaging), while CA methods are P-invariant.
        let (ds, lam, r) = setup();
        let mk = || CocoaOpts {
            lam,
            rounds: 25,
            local_iters: 200,
            seed: 9,
            record_every: 0,
            overlap: false,
        };
        let mut finals = Vec::new();
        for p in [1usize, 4] {
            let shards = partition_primal(&ds, p).unwrap();
            let opts = mk();
            let rref = &r;
            let outs = run_spmd(p, move |rank, comm| {
                let sh = &shards[rank];
                run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, Some(rref), comm).unwrap()
            });
            finals.push(outs[0].history.final_sol_err());
        }
        assert!(
            (finals[0] - finals[1]).abs() > 1e-9,
            "CoCoA P=1 vs P=4 should differ (got {} vs {})",
            finals[0],
            finals[1]
        );
    }
}
