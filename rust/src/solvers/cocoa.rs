//! CoCoA-style communication-efficient baseline (Jaggi et al. [24]) — the
//! framework the paper contrasts against in §1: it reduces communication by
//! running dual coordinate descent on *locally stored* data points and
//! intermittently averaging, but — unlike the CA transformation — it
//! **changes the convergence behaviour** (and communicates fewer times only
//! heuristically). This implementation exists to demonstrate exactly that
//! contrast (see the `ablation_cocoa` bench and the trajectory test below).
//!
//! One round: every rank performs `local_iters` single-coordinate dual
//! updates (SDCA with least-squares loss, b′=1) over its own data points
//! against a stale local copy of w, then the Δw contributions are averaged
//! (γ = 1/P, the safe CoCoA combiner) with ONE allreduce. The round loop
//! runs through the shared pipeline core ([`crate::engine::drive`]) with a
//! `d`-word state-only payload; in overlap mode the engine hides the
//! local dual-block commit (independent of the combined Δw) behind the
//! in-flight non-blocking reduction — bitwise identical to blocking.
//!
//! Note on the packed-Gram wire format used by the CA solvers: CoCoA has
//! no `[G|r]` payload to pack — its one collective per round is the
//! length-`d` Δw combine, already minimal (exactly `d` words/rank/round;
//! asserted alongside the packed-payload word counts in
//! `tests/packed_gram.rs`).

use crate::comm::Communicator;
use crate::engine::{drive, CaStep, Checkpoint, Sample};
use crate::error::Result;
use crate::matrix::Matrix;
use crate::metrics::{relative_objective_error, relative_solution_error, History, IterRecord,
    Reference};
use crate::prox::Reg;
use crate::sampling::BlockSampler;
use crate::solvers::common::{metered_out, objective_value, SolverOpts};

/// CoCoA options.
#[derive(Clone, Debug)]
pub struct CocoaOpts {
    /// Regularization λ.
    pub lam: f64,
    /// Outer (communication) rounds.
    pub rounds: usize,
    /// Local dual coordinate updates per round.
    pub local_iters: usize,
    /// Base sampling seed (decorrelated per rank — CoCoA *wants* each
    /// rank to walk its own coordinates).
    pub seed: u64,
    /// Record convergence metrics every this many rounds (0 = start/end).
    pub record_every: usize,
    /// Reduce the Δw contribution with the non-blocking allreduce, hiding
    /// it behind the local dual-block commit (which is independent of the
    /// combined Δw). Bitwise identical to the blocking path.
    pub overlap: bool,
}

impl Default for CocoaOpts {
    fn default() -> Self {
        CocoaOpts {
            lam: 1e-3,
            rounds: 100,
            local_iters: 100,
            seed: 0,
            record_every: 0,
            overlap: false,
        }
    }
}

/// Output: replicated w, this rank's dual slice, history.
#[derive(Clone, Debug)]
pub struct CocoaOutput {
    /// Replicated primal iterate.
    pub w: Vec<f64>,
    /// This rank's dual block.
    pub alpha_loc: Vec<f64>,
    /// Trajectory + communication accounting of the run.
    pub history: History,
}

/// Run CoCoA on this rank's 1D-block-column shard of X.
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    opts: &CocoaOpts,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<CocoaOutput> {
    let d = a_loc.rows();
    let n_loc = a_loc.cols();

    // Local columns as rows of Aᵀ for cheap column access.
    let at = a_loc.transpose(); // n_loc × d
    // Per-point squared norms ‖x_j‖² (the SDCA denominator).
    let mut col_norms = vec![0.0; n_loc];
    for j in 0..n_loc {
        let mut row = vec![0.0; d];
        at.gather_rows(&[j], &mut row)?;
        col_norms[j] = row.iter().map(|v| v * v).sum();
    }
    // Rank-decorrelated sampling (unlike the CA solvers, CoCoA WANTS each
    // rank to walk its own coordinates).
    let sampler = if n_loc > 0 {
        Some(BlockSampler::new(
            n_loc,
            opts.seed ^ (comm.rank() as u64) << 32,
        ))
    } else {
        None
    };

    let mut history = History::default();
    let mut step = CocoaStep {
        a_loc,
        y_loc,
        n_global,
        reference,
        at,
        col_norms,
        sampler,
        lam: opts.lam,
        n: n_global as f64,
        p: comm.size() as f64,
        local_iters: opts.local_iters,
        w: vec![0.0; d],
        alpha_loc: vec![0.0; n_loc],
        alpha_work: vec![0.0; n_loc],
        xrow: vec![0.0; d],
    };
    // Map the round loop onto the engine's outer loop: one round = one
    // outer iteration with s = 1 and a d-word state-only payload. The
    // engine's record cadence with s = 1 reproduces CoCoA's
    // `round % record_every == 0` exactly.
    let eopts = SolverOpts::builder()
        .b(1)
        .s(1)
        .lam(opts.lam)
        .iters(opts.rounds)
        .seed(opts.seed)
        .record_every(opts.record_every)
        .overlap(opts.overlap)
        .reg(Reg::L2)
        .build();
    drive(&mut step, &eopts, comm, &mut history)?;
    Ok(CocoaOutput {
        w: step.w,
        alpha_loc: step.alpha_loc,
        history,
    })
}

/// CoCoA's per-round callbacks: the whole SDCA local phase is the
/// state-dependent payload production (nothing is prefetchable — the
/// local solve reads the evolving w), and the dual-block commit is the
/// hidden work the overlap schedule runs under the in-flight Δw combine.
struct CocoaStep<'a> {
    a_loc: &'a Matrix,
    y_loc: &'a [f64],
    n_global: usize,
    reference: Option<&'a Reference>,
    at: Matrix,
    col_norms: Vec<f64>,
    sampler: Option<BlockSampler>,
    lam: f64,
    n: f64,
    p: f64,
    local_iters: usize,
    w: Vec<f64>,
    alpha_loc: Vec<f64>,
    alpha_work: Vec<f64>,
    xrow: Vec<f64>,
}

impl<C: Communicator> CaStep<C> for CocoaStep<'_> {
    fn payload_split(&self) -> (usize, usize) {
        (0, self.w.len())
    }

    fn sample(&mut self, _comm: &mut C, k: usize) -> Result<Sample> {
        // CoCoA samples rank-locally inside the SDCA epoch.
        Ok(Sample::empty(k))
    }

    fn local_gram(&mut self, _comm: &mut C, _smp: &Sample, _head: &mut [f64]) -> Result<()> {
        Ok(()) // no sample-dependent payload — the head is empty
    }

    fn local_state(&mut self, _smp: &Sample, tail: &mut [f64]) -> Result<()> {
        // Local phase: SDCA epochs against a frozen w, on a WORKING copy
        // of the local dual block (committed scaled by γ in `hidden_work`
        // / `apply` — the CoCoA-v1 averaging combiner, which keeps
        // w = −(1/λn)·Xα exact). `tail` accumulates this rank's Δw.
        tail.fill(0.0);
        let mut w_local = self.w.clone();
        self.alpha_work.copy_from_slice(&self.alpha_loc);
        let (lam, n) = (self.lam, self.n);
        if let Some(sampler) = self.sampler.as_mut() {
            for _ in 0..self.local_iters {
                let j = sampler.draw_block(1)[0];
                self.at.gather_rows(&[j], &mut self.xrow)?;
                // Single-coordinate dual step (eq. 17 with b′=1):
                // θ = ‖x_j‖²/(λn²) + 1/n ; Δα = −(1/n)·θ⁻¹(−x_jᵀw + α_j + y_j)
                let theta = self.col_norms[j] / (lam * n * n) + 1.0 / n;
                let xw: f64 = self.xrow.iter().zip(&w_local).map(|(a, b)| a * b).sum();
                let rhs = -xw + self.alpha_work[j] + self.y_loc[j];
                let da = -(1.0 / n) * rhs / theta;
                self.alpha_work[j] += da;
                let scale = -da / (lam * n);
                for (t, &xv) in self.xrow.iter().enumerate() {
                    w_local[t] += scale * xv;
                    tail[t] += scale * xv;
                }
            }
        }
        Ok(())
    }

    fn hidden_work(&mut self, _smp: &Sample) -> Result<()> {
        // Combine with γ = 1/P, dual side: α_[k] += γΔα_[k]. Independent
        // of the combined Δw, so the overlap schedule hides it under the
        // in-flight reduction. The averaging preserves the primal-dual
        // coupling but damps every machine's progress — the "changes the
        // convergence behavior" contrast the paper draws against the CA
        // transformation.
        for (a, &work) in self.alpha_loc.iter_mut().zip(&self.alpha_work) {
            *a += (work - *a) / self.p;
        }
        Ok(())
    }

    fn inner_solve(&mut self, _smp: &Sample, _head: &[f64], _tail: &[f64]) -> Result<Vec<f64>> {
        // Nothing to solve — the reduced ΣΔw IS the update; the empty
        // result tells the engine to apply the payload tail zero-copy.
        Ok(Vec::new())
    }

    fn apply(&mut self, _smp: &Sample, deltas: &[f64]) -> Result<()> {
        // Primal side of the γ = 1/P combine: w += γ·ΣΔw_k.
        for (wi, dv) in self.w.iter_mut().zip(deltas) {
            *wi += dv / self.p;
        }
        Ok(())
    }

    fn record(&mut self, comm: &mut C, history: &mut History, h_now: usize) -> Result<()> {
        record(
            history,
            h_now,
            &self.w,
            self.a_loc,
            self.y_loc,
            self.n_global,
            self.lam,
            self.reference,
            comm,
        )
    }

    fn ckpt_kind(&self) -> &'static str {
        "cocoa"
    }

    fn save_state(&self, ckpt: &mut Checkpoint) -> Result<()> {
        // alpha_work / xrow are scratch (re-seeded from alpha_loc at the
        // top of every local phase); the rank-decorrelated sampler RNG
        // plus the two iterates are the whole mutable state. Empty shards
        // have no sampler and store no RNG words.
        if let Some(sampler) = self.sampler.as_ref() {
            ckpt.rng = sampler.rng_state().to_vec();
        }
        ckpt.push_f64("w", &self.w);
        ckpt.push_f64("alpha_loc", &self.alpha_loc);
        Ok(())
    }

    fn restore_state(&mut self, ckpt: &Checkpoint) -> Result<()> {
        if let Some(sampler) = self.sampler.as_mut() {
            sampler.set_rng_state(ckpt.rng_words()?);
        }
        ckpt.read_f64_into("w", &mut self.w)?;
        ckpt.read_f64_into("alpha_loc", &mut self.alpha_loc)
    }
}

#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    w: &[f64],
    a_loc: &Matrix,
    y_loc: &[f64],
    n_global: usize,
    lam: f64,
    reference: Option<&Reference>,
    comm: &mut C,
) -> Result<()> {
    let Some(r) = reference else { return Ok(()) };
    let resid_sq = metered_out(comm, |c| {
        let mut xtw = vec![0.0; a_loc.cols()];
        a_loc.matvec_t(w, &mut xtw)?;
        let mut part = [xtw
            .iter()
            .zip(y_loc)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()];
        c.allreduce_sum(&mut part)?;
        Ok(part[0])
    })?;
    let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
    let f_alg = objective_value(resid_sq, w_norm_sq, n_global, lam);
    history.records.push(IterRecord {
        iter,
        obj_err: relative_objective_error(f_alg, r.f_opt),
        sol_err: relative_solution_error(w, &r.w_opt),
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::thread::run_spmd;
    use crate::comm::SerialComm;
    use crate::coordinator::partition_primal;
    use crate::matrix::gen::{generate, scaled_specs};
    use crate::matrix::io::Dataset;
    use crate::solvers::cg;

    fn setup() -> (Dataset, f64, crate::metrics::Reference) {
        let spec = &scaled_specs(8)[0]; // abalone-s8
        let ds = generate(spec, 4).unwrap();
        let lam = spec.lambda();
        let mut comm = SerialComm::new();
        let r = cg::compute_reference(&ds.x, &ds.y, ds.n(), lam, &mut comm).unwrap();
        (ds, lam, r)
    }

    #[test]
    fn cocoa_converges_toward_optimum() {
        let (ds, lam, r) = setup();
        // Overlap mode: exercises the non-blocking Δw reduction SPMD (the
        // trajectory and the one-allreduce-per-round count are unchanged).
        let opts = CocoaOpts {
            lam,
            rounds: 150,
            local_iters: 400,
            seed: 1,
            record_every: 0,
            overlap: true,
        };
        let shards = partition_primal(&ds, 2).unwrap();
        let opts2 = opts.clone();
        let rref = &r;
        let outs = run_spmd(2, move |rank, comm| {
            let sh = &shards[rank];
            run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts2, Some(rref), comm).unwrap()
        });
        let err = outs[0].history.final_sol_err();
        // γ=1/P averaging converges slowly — the paper's point: the
        // communication saving comes WITH a convergence-behaviour change.
        assert!(err < 0.15, "CoCoA made too little progress: {err}");
        // One allreduce per round — the communication-efficiency claim.
        assert_eq!(outs[0].history.meter.allreduces, 150);
    }

    #[test]
    fn cocoa_changes_convergence_with_rank_count_unlike_ca() {
        // The paper's §1 contrast: CoCoA's trajectory DEPENDS on P (local
        // solves + averaging), while CA methods are P-invariant.
        let (ds, lam, r) = setup();
        let mk = || CocoaOpts {
            lam,
            rounds: 25,
            local_iters: 200,
            seed: 9,
            record_every: 0,
            overlap: false,
        };
        let mut finals = Vec::new();
        for p in [1usize, 4] {
            let shards = partition_primal(&ds, p).unwrap();
            let opts = mk();
            let rref = &r;
            let outs = run_spmd(p, move |rank, comm| {
                let sh = &shards[rank];
                run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, Some(rref), comm).unwrap()
            });
            finals.push(outs[0].history.final_sol_err());
        }
        assert!(
            (finals[0] - finals[1]).abs() > 1e-9,
            "CoCoA P=1 vs P=4 should differ (got {} vs {})",
            finals[0],
            finals[1]
        );
    }
}
