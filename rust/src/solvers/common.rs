//! Shared solver plumbing: options, outputs, and the meter-excluded metric
//! evaluation helpers.

use crate::comm::Communicator;
use crate::error::Result;
use crate::metrics::History;
use crate::prox::Reg;

/// Options shared by every coordinate-descent variant.
///
/// `#[non_exhaustive]`: construct via [`SolverOpts::builder`] (or
/// [`SolverOpts::default`] + field mutation) outside this crate, so the
/// next field addition does not touch every literal in the tree again.
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct SolverOpts {
    /// Block size (b for primal, b' for dual).
    pub b: usize,
    /// Loop-blocking factor; 1 = the classical algorithm.
    pub s: usize,
    /// Regularization λ.
    pub lam: f64,
    /// Total inner iterations H (rounded down to a multiple of `s`).
    pub iters: usize,
    /// Shared sampling seed (identical on every rank — §3.1).
    pub seed: u64,
    /// Record convergence metrics every this many inner iterations
    /// (0 = record only at start/end).
    pub record_every: usize,
    /// Track the Gram-matrix condition number each outer iteration
    /// (Figures 4/7; costs an sb×sb Jacobi eigensolve per record).
    pub track_gram_cond: bool,
    /// Early stop once |objective error| ≤ tol (needs a reference).
    pub tol: Option<f64>,
    /// Overlap communication with computation: reduce the `[G | r]` buffer
    /// with the non-blocking allreduce and hide it behind the *next* outer
    /// iteration's local Gram computation (which depends only on X and the
    /// shared-seed sample stream, not on the evolving α/w state). The
    /// trajectory is bitwise identical to the blocking path and the
    /// allreduce count stays exactly H/s.
    pub overlap: bool,
    /// Regularizer `ψ(w)` of the penalized objective
    /// `‖Xᵀw − y‖²/(2n) + ψ(w)`. [`Reg::L2`] (the default) takes the
    /// pre-existing exact-Cholesky solvers bitwise unchanged; every other
    /// choice routes `bcd`/`bdcd` through the CA-Prox loops
    /// ([`crate::prox`]) — same packed `[G|r]` payload, same H/s
    /// collective count.
    pub reg: Reg,
}

impl Default for SolverOpts {
    fn default() -> Self {
        SolverOpts {
            b: 4,
            s: 1,
            lam: 1e-3,
            iters: 1000,
            seed: 0,
            record_every: 10,
            track_gram_cond: false,
            tol: None,
            overlap: false,
            reg: Reg::L2,
        }
    }
}

/// Fluent constructor for [`SolverOpts`] (the struct is
/// `#[non_exhaustive]`, so cross-crate callers build it here). Unset
/// fields keep the [`SolverOpts::default`] values; validation stays in
/// [`SolverOpts::validate`] (called by every solver entry point).
#[derive(Clone, Debug, Default)]
pub struct SolverOptsBuilder {
    opts: SolverOpts,
}

impl SolverOptsBuilder {
    /// Block size (b for primal, b' for dual).
    pub fn b(mut self, b: usize) -> Self {
        self.opts.b = b;
        self
    }

    /// Loop-blocking factor; 1 = the classical algorithm.
    pub fn s(mut self, s: usize) -> Self {
        self.opts.s = s;
        self
    }

    /// Regularization λ.
    pub fn lam(mut self, lam: f64) -> Self {
        self.opts.lam = lam;
        self
    }

    /// Total inner iterations H (rounded down to a multiple of `s`).
    pub fn iters(mut self, iters: usize) -> Self {
        self.opts.iters = iters;
        self
    }

    /// Shared sampling seed (identical on every rank — §3.1).
    pub fn seed(mut self, seed: u64) -> Self {
        self.opts.seed = seed;
        self
    }

    /// Record cadence in inner iterations (0 = start/end only).
    pub fn record_every(mut self, record_every: usize) -> Self {
        self.opts.record_every = record_every;
        self
    }

    /// Track the Gram condition number each outer iteration.
    pub fn track_gram_cond(mut self, track: bool) -> Self {
        self.opts.track_gram_cond = track;
        self
    }

    /// Early stop once the method's certificate reaches `tol`.
    pub fn tol(mut self, tol: f64) -> Self {
        self.opts.tol = Some(tol);
        self
    }

    /// Overlap communication with computation (non-blocking pipeline).
    pub fn overlap(mut self, overlap: bool) -> Self {
        self.opts.overlap = overlap;
        self
    }

    /// Regularizer ψ(w) (non-L2 routes through the CA-Prox loops).
    pub fn reg(mut self, reg: Reg) -> Self {
        self.opts.reg = reg;
        self
    }

    /// Finish building.
    pub fn build(self) -> SolverOpts {
        self.opts
    }
}

impl SolverOpts {
    /// Start a [`SolverOptsBuilder`] seeded with the default options.
    pub fn builder() -> SolverOptsBuilder {
        SolverOptsBuilder::default()
    }

    /// Sanity-check the options against the sampled dimension (the
    /// primal feature count d or the dual point count n).
    pub fn validate(&self, sample_dim: usize) -> Result<()> {
        use crate::error::Error;
        if self.b == 0 || self.s == 0 {
            return Err(Error::InvalidArg("b and s must be ≥ 1".into()));
        }
        if self.b > sample_dim {
            return Err(Error::InvalidArg(format!(
                "block size {} > sampled dimension {}",
                self.b, sample_dim
            )));
        }
        if self.lam <= 0.0 {
            return Err(Error::InvalidArg("λ must be > 0".into()));
        }
        self.reg.validate()?;
        Ok(())
    }

    /// Number of outer iterations (each costing one allreduce).
    pub fn outer_iters(&self) -> usize {
        self.iters / self.s
    }
}

/// Output of the primal solvers: replicated `w`, this rank's α slice.
#[derive(Clone, Debug)]
pub struct PrimalOutput {
    /// Replicated primal solution.
    pub w: Vec<f64>,
    /// This rank's slice of α = Xᵀw.
    pub alpha_loc: Vec<f64>,
    /// Trajectory + communication accounting of the run.
    pub history: History,
}

/// Output of the dual solvers: this rank's `w` slice, replicated α, and —
/// gathered once at the end for convenience — the full `w`.
#[derive(Clone, Debug)]
pub struct DualOutput {
    /// This rank's slice of the primal vector.
    pub w_loc: Vec<f64>,
    /// Full primal vector (assembled once at the end, metric path).
    pub w_full: Vec<f64>,
    /// Replicated dual solution.
    pub alpha: Vec<f64>,
    /// Trajectory + communication accounting of the run.
    pub history: History,
}

/// Condition-tracking sampling stride shared by every solver loop:
/// exact-per-iteration for small Gram matrices, ~16 samples for large sb
/// (the Figs. 4i–l / 7i–l regimes, sb up to 3200).
pub fn cond_stride(sb: usize, outer: usize) -> usize {
    if sb <= 128 {
        1
    } else {
        outer.div_ceil(16).max(1)
    }
}

/// Diagnostic-path condition estimate of `scale·G + shift·I`, where G is
/// the allreduced packed lower triangle: mirror into `scratch` (`sb²`)
/// for the eigensolver and run the power/inverse-power estimator. Shared
/// by the smooth and prox loops so the mirror indexing and estimator
/// policy cannot drift between them.
pub fn packed_gram_cond(packed: &[f64], sb: usize, scale: f64, shift: f64, scratch: &mut [f64]) -> f64 {
    debug_assert!(scratch.len() >= sb * sb);
    for i in 0..sb {
        for j in 0..sb {
            scratch[i * sb + j] = scale * packed[crate::linalg::packed::pidx(i, j)]
                + if i == j { shift } else { 0.0 };
        }
    }
    crate::linalg::cond::condition_number(scratch, sb)
}

/// Record cadence shared by every solver loop: record at the first outer
/// boundary at or past each `record_every` mark (0 = start/end only).
pub fn should_record(h_now: usize, s: usize, opts: &SolverOpts) -> bool {
    if opts.record_every == 0 {
        return false;
    }
    let re = opts.record_every.max(s);
    h_now % ((re / s).max(1) * s) == 0
}

/// Flatten `s` sampled blocks of size `b` into a contiguous index list
/// (the layout every [`crate::gram::ComputeBackend`] kernel consumes).
pub fn flatten_blocks(blocks: &[Vec<usize>], b: usize, idx_flat: &mut [usize]) {
    for (j, blk) in blocks.iter().enumerate() {
        for (i, &row) in blk.iter().enumerate() {
            idx_flat[j * b + i] = row;
        }
    }
}

/// Run `f` (metric-evaluation communication) without polluting the solver's
/// cost meter: snapshot, run, restore. The span tracer and the telemetry
/// registry are paused for the same scope, so diagnostic collectives stay
/// invisible to the meters, the trace, and the health metrics — keeping
/// the span-count/meter cross-check gate (`crate::trace::cross_check`)
/// exact.
pub fn metered_out<C: Communicator, T>(
    comm: &mut C,
    f: impl FnOnce(&mut C) -> Result<T>,
) -> Result<T> {
    let snap = *comm.meter();
    let _trace_pause = crate::trace::pause();
    let _telemetry_pause = crate::telemetry::pause();
    let out = f(comm);
    *comm.meter_mut() = snap;
    out
}

/// The primal objective `f(X,w,y) = 1/(2n)·‖Xᵀw−y‖² + λ/2·‖w‖²` from its
/// two building blocks.
pub fn objective_value(residual_sq: f64, w_norm_sq: f64, n: usize, lam: f64) -> f64 {
    residual_sq / (2.0 * n as f64) + 0.5 * lam * w_norm_sq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Communicator, SerialComm};

    #[test]
    fn opts_validation() {
        let mut o = SolverOpts::default();
        assert!(o.validate(100).is_ok());
        o.b = 0;
        assert!(o.validate(100).is_err());
        o.b = 200;
        assert!(o.validate(100).is_err());
        o.b = 4;
        o.lam = 0.0;
        assert!(o.validate(100).is_err());
    }

    #[test]
    fn outer_iters_floor() {
        let o = SolverOpts {
            iters: 103,
            s: 10,
            ..Default::default()
        };
        assert_eq!(o.outer_iters(), 10);
    }

    #[test]
    fn metered_out_restores() {
        let mut c = SerialComm::new();
        let mut buf = vec![1.0];
        c.allreduce_sum(&mut buf).unwrap();
        let before = *c.meter();
        metered_out(&mut c, |c| {
            let mut b = vec![2.0];
            c.allreduce_sum(&mut b)?;
            c.allreduce_sum(&mut b)?;
            Ok(())
        })
        .unwrap();
        assert_eq!(*c.meter(), before);
    }

    #[test]
    fn objective_composition() {
        // n=4, λ=0.5, ‖r‖²=8, ‖w‖²=2 → 8/8 + 0.5·0.5·2 = 1.5
        assert_eq!(objective_value(8.0, 2.0, 4, 0.5), 1.5);
    }
}
