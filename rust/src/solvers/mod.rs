#![deny(missing_docs)]
//! The paper's algorithms, SPMD over a [`crate::comm::Communicator`].
//!
//! Every coordinate-descent loop runs through the shared pipeline core of
//! [`crate::engine`] — the modules here contribute the per-method
//! [`CaStep`](crate::engine::CaStep) callbacks plus thin, stably-named
//! `run()` wrappers over the engine's single
//! [`Session`](crate::engine::Session) entry point:
//!
//! * [`bcd`] — Algorithms 1 & 2 (BCD / CA-BCD): one implementation
//!   parameterized by the loop-blocking factor `s` (`s = 1` ≡ Algorithm 1;
//!   the CA≡classical trajectory-equality test exercises `s > 1` against
//!   `s = 1`).
//! * [`bdcd`] — Algorithms 3 & 4 (BDCD / CA-BDCD), same parameterization.
//! * [`cg`] — conjugate gradients on the regularized normal equations
//!   (the paper's Krylov baseline and its ground-truth `w_opt` source).
//! * [`tsqr_ls`] — the TSQR direct baseline (§2.1 survey, Figure 1).
//! * [`bcd_row`] — BCD under the mismatched 1D-block-row layout with the
//!   Theorem-4 all-to-all conversion (and measured Lemma-3 loads).
//! * [`cocoa`] — the CoCoA-style local-solve + average baseline the paper
//!   contrasts against (§1): fewer messages, but P-dependent convergence.

pub mod bcd;
pub mod bcd_row;
pub mod bdcd;
pub mod cg;
pub mod cocoa;
pub mod common;
pub mod tsqr_ls;

pub use common::{DualOutput, PrimalOutput, SolverOpts, SolverOptsBuilder};
