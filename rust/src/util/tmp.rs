//! Scoped temp directories for tests (tempfile crate replacement).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory under the system temp root, removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(tag: &str) -> std::io::Result<TempDir> {
        let id = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "cabcd-{tag}-{}-{id}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let t = TempDir::new("x").unwrap();
            p = t.path().to_path_buf();
            std::fs::write(p.join("f"), "hi").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }
}
