//! Micro property-testing harness (proptest is unavailable offline).
//!
//! `check(cases, |gen| ...)` runs a property against `cases` randomized
//! inputs drawn through a [`Gen`]; on failure it reports the failing seed so
//! the case can be replayed deterministically (`CABCD_PROPTEST_SEED=<seed>`).
//! No shrinking — failing seeds are small enough to debug directly.

use super::rng::Rng64;

/// Randomized-input source handed to properties.
pub struct Gen {
    rng: Rng64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Rng64::seed_from_u64(seed),
            seed,
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.gen_range(lo, hi)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.gen_f64()
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.gen_normal()
    }

    pub fn vec_normal(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.normal()).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Distinct indices from [0, dim).
    pub fn distinct(&mut self, count: usize, dim: usize) -> Vec<usize> {
        assert!(count <= dim);
        let mut pool: Vec<usize> = (0..dim).collect();
        let mut out = Vec::with_capacity(count);
        for k in 0..count {
            let j = self.usize_in(k, dim);
            pool.swap(k, j);
            out.push(pool[k]);
        }
        out
    }
}

/// Run `prop` on `cases` random inputs; panic with the failing seed on the
/// first failure (Err or panic message returned as Err).
pub fn check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    // Replay hook.
    if let Ok(s) = std::env::var("CABCD_PROPTEST_SEED") {
        let seed: u64 = s.parse().expect("CABCD_PROPTEST_SEED must be u64");
        let mut gen = Gen::new(seed);
        if let Err(msg) = prop(&mut gen) {
            panic!("property failed at replayed seed {seed}: {msg}");
        }
        return;
    }
    for case in 0..cases {
        // Derived but well-spread seeds.
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut gen = Gen::new(seed);
        if let Err(msg) = prop(&mut gen) {
            panic!(
                "property failed at case {case} (replay: CABCD_PROPTEST_SEED={seed}): {msg}"
            );
        }
    }
}

/// Helper assertion macros for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b) = ($a, $b);
        if (a - b).abs() > $tol {
            return Err(format!(
                "{} = {a} differs from {} = {b} by {} (tol {})",
                stringify!($a),
                stringify!($b),
                (a - b).abs(),
                $tol
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(32, |g| {
            count += 1;
            let v = g.usize_in(0, 10);
            if v < 10 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(8, |g| {
            if g.usize_in(0, 4) == 2 {
                Err("hit the bad value".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn distinct_is_distinct() {
        check(16, |g| {
            let dim = g.usize_in(5, 50);
            let count = g.usize_in(1, dim + 1).min(dim);
            let idx = g.distinct(count, dim);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            prop_assert!(sorted.len() == count, "duplicates in {idx:?}");
            Ok(())
        });
    }
}
