//! Minimal JSON *emission* (reports, histories). No parser — everything the
//! Rust side reads is INI/TSV (see [`super::ini`] and the artifact
//! manifest); JSON is only written for downstream tooling.

/// Incremental JSON object/array writer.
#[derive(Default)]
pub struct JsonWriter {
    buf: String,
}

impl JsonWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn finish(self) -> String {
        self.buf
    }

    pub fn raw(&mut self, s: &str) -> &mut Self {
        self.buf.push_str(s);
        self
    }

    pub fn string(&mut self, s: &str) -> &mut Self {
        self.buf.push('"');
        for c in s.chars() {
            match c {
                '"' => self.buf.push_str("\\\""),
                '\\' => self.buf.push_str("\\\\"),
                '\n' => self.buf.push_str("\\n"),
                '\r' => self.buf.push_str("\\r"),
                '\t' => self.buf.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    self.buf.push_str(&format!("\\u{:04x}", c as u32));
                }
                c => self.buf.push(c),
            }
        }
        self.buf.push('"');
        self
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        if v.is_finite() {
            self.buf.push_str(&format!("{v}"));
        } else {
            // JSON has no Infinity/NaN; emit null like serde_json does.
            self.buf.push_str("null");
        }
        self
    }
}

/// Format a list of `(key, json-value)` pairs as an object.
pub fn object(fields: &[(&str, String)]) -> String {
    let mut w = JsonWriter::new();
    w.raw("{");
    for (i, (k, v)) in fields.iter().enumerate() {
        if i > 0 {
            w.raw(",");
        }
        w.string(k);
        w.raw(":");
        w.raw(v);
    }
    w.raw("}");
    w.finish()
}

pub fn string(s: &str) -> String {
    let mut w = JsonWriter::new();
    w.string(s);
    w.finish()
}

pub fn num(v: f64) -> String {
    let mut w = JsonWriter::new();
    w.f64(v);
    w.finish()
}

pub fn array<I: IntoIterator<Item = String>>(items: I) -> String {
    let mut out = String::from("[");
    for (i, it) in items.into_iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&it);
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_strings() {
        assert_eq!(string("a\"b\\c\nd"), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn object_and_array() {
        let o = object(&[
            ("x", num(1.5)),
            ("name", string("hi")),
            ("xs", array(vec![num(1.0), num(2.0)])),
        ]);
        assert_eq!(o, r#"{"x":1.5,"name":"hi","xs":[1,2]}"#);
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(num(f64::INFINITY), "null");
        assert_eq!(num(f64::NAN), "null");
    }
}
