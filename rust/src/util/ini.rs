//! Minimal INI-style config parser: `[section]` headers and `key = value`
//! pairs, `#`/`;` comments. Replaces the toml crate for experiment configs
//! and the artifact manifest.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed file: section → (key → value). Keys before any `[section]` land
/// in the "" section.
pub type Ini = BTreeMap<String, BTreeMap<String, String>>;

pub fn parse(text: &str) -> Result<Ini> {
    let mut out: Ini = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| {
                Error::Config(format!("line {}: unterminated section header", lineno + 1))
            })?;
            section = name.trim().to_string();
            out.entry(section.clone()).or_default();
        } else if let Some((k, v)) = line.split_once('=') {
            let v = v.trim().trim_matches('"');
            out.entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v.to_string());
        } else {
            return Err(Error::Config(format!(
                "line {}: expected `key = value` or `[section]`, got {line:?}",
                lineno + 1
            )));
        }
    }
    Ok(out)
}

/// Typed getters over one section.
pub struct Section<'a> {
    pub name: &'a str,
    map: Option<&'a BTreeMap<String, String>>,
}

impl<'a> Section<'a> {
    pub fn of(ini: &'a Ini, name: &'a str) -> Section<'a> {
        Section {
            name,
            map: ini.get(name),
        }
    }

    pub fn str(&self, key: &str) -> Option<&'a str> {
        self.map.and_then(|m| m.get(key)).map(|s| s.as_str())
    }

    pub fn require(&self, key: &str) -> Result<&'a str> {
        self.str(key).ok_or_else(|| {
            Error::Config(format!("[{}] missing required key `{key}`", self.name))
        })
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.str(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| {
                Error::Config(format!("[{}] {key}: bad integer {v:?}: {e}", self.name))
            }),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.str(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| {
                Error::Config(format!("[{}] {key}: bad integer {v:?}: {e}", self.name))
            }),
        }
    }

    pub fn u64_opt(&self, key: &str) -> Result<Option<u64>> {
        match self.str(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|e| {
                Error::Config(format!("[{}] {key}: bad integer {v:?}: {e}", self.name))
            }),
        }
    }

    pub fn f64_opt(&self, key: &str) -> Result<Option<f64>> {
        match self.str(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|e| {
                Error::Config(format!("[{}] {key}: bad float {v:?}: {e}", self.name))
            }),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.str(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(Error::Config(format!(
                "[{}] {key}: bad bool {v:?}",
                self.name
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let ini = parse(
            r#"
            # comment
            [dataset]
            kind = synthetic
            name = "abalone"

            [solver]
            b = 8
            lam = 4.3e-2
            track = true
            "#,
        )
        .unwrap();
        let ds = Section::of(&ini, "dataset");
        assert_eq!(ds.require("kind").unwrap(), "synthetic");
        assert_eq!(ds.str("name"), Some("abalone"));
        let s = Section::of(&ini, "solver");
        assert_eq!(s.usize_or("b", 1).unwrap(), 8);
        assert_eq!(s.f64_opt("lam").unwrap(), Some(4.3e-2));
        assert!(s.bool_or("track", false).unwrap());
        assert_eq!(s.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("not a kv line").is_err());
        assert!(parse("[unterminated").is_err());
    }

    #[test]
    fn missing_required_key_errors() {
        let ini = parse("[a]\nx = 1\n").unwrap();
        assert!(Section::of(&ini, "a").require("y").is_err());
        assert!(Section::of(&ini, "b").require("x").is_err());
    }
}
