//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Replaces rand/rand_chacha (unavailable offline). The paper's shared-seed
//! trick (§3.1) only needs *identical deterministic streams on every rank*;
//! xoshiro256++ passes BigCrush and is trivially reproducible.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng64 {
    s: [u64; 4],
}

impl Rng64 {
    /// Seed via SplitMix64 (the reference seeding procedure).
    pub fn seed_from_u64(seed: u64) -> Rng64 {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng64 {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in `[lo, hi)` (Lemire-style rejection-free for our
    /// purposes: modulo bias is < 2⁻⁵³·range, negligible at our ranges,
    /// but we use widening multiply anyway).
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        let range = (hi - lo) as u64;
        let hi_bits = ((self.next_u64() as u128 * range as u128) >> 64) as u64;
        lo + hi_bits as usize
    }

    /// Snapshot the full generator state (checkpoint/restart support:
    /// xoshiro256++ has no hidden state beyond these four words, so
    /// `from_state(state())` resumes the exact stream).
    #[inline]
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng64::state`] snapshot. The all-zero
    /// state is the xoshiro fixed point (stream of zeros) and can never be
    /// produced by `seed_from_u64`, so it is rejected.
    pub fn from_state(s: [u64; 4]) -> Rng64 {
        assert!(s != [0; 4], "all-zero xoshiro state is degenerate");
        Rng64 { s }
    }

    /// Standard normal via Box–Muller (two uniforms per call, deterministic
    /// stream).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng64::seed_from_u64(42);
        let mut b = Rng64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng64::seed_from_u64(1);
        let mut b = Rng64::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng64::seed_from_u64(7);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds_and_coverage() {
        let mut r = Rng64::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(5, 15);
            assert!((5..15).contains(&v));
            seen[v - 5] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn state_roundtrip_resumes_exact_stream() {
        let mut a = Rng64::seed_from_u64(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        let mut b = Rng64::from_state(snap);
        let resumed: Vec<u64> = (0..50).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
    }

    #[test]
    fn normal_moments_roughly_right() {
        let mut r = Rng64::seed_from_u64(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.gen_normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }
}
