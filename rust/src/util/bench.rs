//! Minimal timing harness for the `benches/` binaries (criterion is not in
//! the offline vendor set). Median-of-runs wall-clock with warmup;
//! black-box via `std::hint::black_box`.

use std::time::Instant;

/// Run `f` `runs` times after `warmup` unmeasured runs; returns
/// (median, min, max) seconds per run.
pub fn time_runs<T>(warmup: usize, runs: usize, mut f: impl FnMut() -> T) -> (f64, f64, f64) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        samples[samples.len() / 2],
        samples[0],
        *samples.last().unwrap(),
    )
}

/// Pretty time formatting.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_ordered() {
        let (med, min, max) = time_runs(1, 5, || {
            let mut s = 0.0f64;
            for i in 0..1000 {
                s += (i as f64).sqrt();
            }
            s
        });
        assert!(min <= med && med <= max);
        assert!(min > 0.0);
    }

    #[test]
    fn formats() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
    }
}
