//! Dependency-free utilities: deterministic PRNG, INI-style key=value
//! config parsing, JSON emission, and a micro property-testing harness.
//!
//! This repo builds fully offline with **zero external dependencies** (the
//! optional PJRT runtime needs a vendored `xla` crate behind
//! `--cfg cabcd_xla`), so the usual ecosystem crates (rand, serde, clap,
//! proptest, criterion, thiserror) are re-implemented here at the scale
//! this project needs.

pub mod ini;
pub mod json;
pub mod proptest;
pub mod bench;
pub mod rng;
pub mod tmp;

pub use rng::Rng64;
