//! Dependency-free utilities: deterministic PRNG, INI-style key=value
//! config parsing, JSON emission, and a micro property-testing harness.
//!
//! This repo builds fully offline against a minimal vendored crate set
//! (xla/anyhow/thiserror), so the usual ecosystem crates (rand, serde,
//! clap, proptest, criterion) are re-implemented here at the scale this
//! project needs.

pub mod ini;
pub mod json;
pub mod proptest;
pub mod bench;
pub mod rng;
pub mod tmp;

pub use rng::Rng64;
