//! 1D data partitioning (paper §4): block-column (data-point) and block-row
//! (feature) layouts, plus the Lemma-3 balls-into-bins load-balance bound
//! that governs the all-to-all fallback cost of the mismatched layouts
//! (Theorems 4/5/8/9).

/// Which dimension of the operand is split across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// 1D-block column: contraction dimension split, sampled rows fully
    /// replicated in pieces — the *matched* layout for row-sampled Gram
    /// computations (BCD on X, BDCD on Xᵀ).
    BlockColumn,
    /// 1D-block row: sample dimension split — requires the Theorem-4
    /// all-to-all conversion before each Gram computation.
    BlockRow,
}

/// Contiguous 1D block partition of `len` items over `p` ranks.
///
/// Invariants (property-tested): blocks are disjoint, ordered, cover
/// `0..len`, and sizes differ by at most one.
#[derive(Clone, Debug)]
pub struct BlockPartition {
    pub len: usize,
    pub p: usize,
}

impl BlockPartition {
    pub fn new(len: usize, p: usize) -> Self {
        assert!(p >= 1);
        BlockPartition { len, p }
    }

    /// Half-open range `[lo, hi)` owned by `rank`.
    pub fn range(&self, rank: usize) -> (usize, usize) {
        let base = self.len / self.p;
        let extra = self.len % self.p;
        let lo = rank * base + rank.min(extra);
        let size = base + usize::from(rank < extra);
        (lo, lo + size)
    }

    pub fn size(&self, rank: usize) -> usize {
        let (lo, hi) = self.range(rank);
        hi - lo
    }

    /// Owner rank of global index `i`.
    pub fn owner(&self, i: usize) -> usize {
        assert!(i < self.len);
        let base = self.len / self.p;
        let extra = self.len % self.p;
        let split = extra * (base + 1);
        if i < split {
            i / (base + 1)
        } else if base == 0 {
            // len < p: only the first `extra` ranks own anything.
            self.p - 1 // unreachable via assert above when base==0 && i>=split
        } else {
            extra + (i - split) / base
        }
    }
}

/// Lemma 3: with `b` blocks ("balls") sampled uniformly over ranks, the
/// worst-case max load on one rank is `O(ln b / ln ln b)` w.h.p.
/// Returned as a concrete bound used by the cost model's all-to-all term.
pub fn max_load_bound(b: usize) -> f64 {
    if b <= 2 {
        return b as f64;
    }
    let lb = (b as f64).ln();
    let llb = lb.ln().max(1e-9);
    lb / llb
}

/// Tighter bound when `b < P / log P` (Mitzenmacher): `O(log P / log(P/b))`.
pub fn max_load_bound_small_b(b: usize, p: usize) -> f64 {
    if p <= 1 || b == 0 {
        return b as f64;
    }
    let lp = (p as f64).ln();
    let ratio = (p as f64 / b as f64).ln().max(1e-9);
    lp / ratio
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_exactly() {
        for len in [0usize, 1, 7, 100, 101] {
            for p in [1usize, 2, 3, 7, 16] {
                let part = BlockPartition::new(len, p);
                let mut covered = 0;
                let mut prev_hi = 0;
                for r in 0..p {
                    let (lo, hi) = part.range(r);
                    assert_eq!(lo, prev_hi, "len={len} p={p} r={r}");
                    prev_hi = hi;
                    covered += hi - lo;
                }
                assert_eq!(covered, len);
                assert_eq!(prev_hi, len);
            }
        }
    }

    #[test]
    fn sizes_balanced_within_one() {
        let part = BlockPartition::new(103, 8);
        let sizes: Vec<usize> = (0..8).map(|r| part.size(r)).collect();
        let mx = *sizes.iter().max().unwrap();
        let mn = *sizes.iter().min().unwrap();
        assert!(mx - mn <= 1);
    }

    #[test]
    fn owner_consistent_with_range() {
        for len in [13usize, 64, 99] {
            for p in [1usize, 3, 5, 10] {
                let part = BlockPartition::new(len, p);
                for i in 0..len {
                    let o = part.owner(i);
                    let (lo, hi) = part.range(o);
                    assert!(lo <= i && i < hi, "len={len} p={p} i={i} o={o}");
                }
            }
        }
    }

    #[test]
    fn lemma3_bound_grows_slowly() {
        let b8 = max_load_bound(8);
        let b1024 = max_load_bound(1024);
        assert!(b8 < b1024);
        assert!(b1024 < 10.0, "ln b / ln ln b stays tiny: {b1024}");
        assert!(max_load_bound_small_b(4, 1024) < 2.0);
    }
}
