//! `ca_lint` — run the project's SPMD hygiene lint from the command line.
//!
//! Usage: `cargo run --bin ca_lint [src-root]` (default `rust/src`).
//! Exits 0 when clean, 1 on violations, 2 on IO failure — CI runs it as
//! a gating step, and `rust/tests/analysis.rs` runs the same pass as the
//! `lint_is_clean_and_allowlist_is_frozen` gate test.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "rust/src".to_string());
    match cabcd::analysis::run_lint(Path::new(&root)) {
        Ok(report) => {
            print!("{report}");
            if report.is_clean() {
                ExitCode::SUCCESS
            } else {
                eprintln!(
                    "ca_lint: FAIL — fix the site(s) or re-audit ALLOW in \
                     rust/src/analysis/lint.rs (counts ratchet both ways)"
                );
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("ca_lint: cannot scan {root}: {e}");
            ExitCode::from(2)
        }
    }
}
