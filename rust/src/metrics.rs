//! Convergence metrics and histories.
//!
//! The paper reports two error measures (§5.1):
//! * relative solution error  `‖w_opt − w_h‖₂ / ‖w_opt‖₂`
//! * relative objective error `(f(X,w_h,y) − f(X,w_opt,y)) / f(X,w_opt,y)`
//!   (plotted as |·|; we store the signed value and plot magnitude)
//!
//! with `f(X,w,y) = 1/(2n)‖Xᵀw − y‖² + λ/2‖w‖²`, and additionally the Gram
//! condition-number statistics of Figures 4/7.

use crate::comm::CostMeter;

/// Ground truth for error measurement: `w_opt` from CG at tol 1e-15 plus
/// its objective value (paper §5.1).
#[derive(Clone, Debug)]
pub struct Reference {
    pub w_opt: Vec<f64>,
    pub f_opt: f64,
}

/// One recorded point of a convergence trajectory.
#[derive(Clone, Copy, Debug)]
pub struct IterRecord {
    /// Inner-iteration index h (CA variants record at outer boundaries).
    pub iter: usize,
    /// Relative objective error (may be ~0 negative due to roundoff).
    pub obj_err: f64,
    /// Relative solution error.
    pub sol_err: f64,
}

/// One recorded point of a proximal (non-smooth) solver trajectory — the
/// certificates the CA-Prox solvers report instead of reference-relative
/// errors (no closed-form `w_opt` exists for L1/elastic-net problems).
#[derive(Clone, Copy, Debug)]
pub struct ProxRecord {
    /// Inner-iteration index h (outer boundaries, like [`IterRecord`]).
    pub iter: usize,
    /// Penalized objective `P(w) = ‖Xᵀw − y‖²/(2n) + ψ(w)` (primal
    /// solvers) or `D(α) + ψ(α)` (dual solvers).
    pub pen_obj: f64,
    /// Fenchel duality gap from the scaled-residual dual candidate
    /// (primal L1/L2/elastic; `NaN` where no conjugate certificate
    /// applies — `Reg::None` and the dual solvers).
    pub gap: f64,
    /// ℓ2 norm of the minimum-norm subgradient of the penalized objective
    /// at the iterate (zero iff optimal).
    pub subgrad: f64,
    /// Exact zeros in the iterate (soft thresholding produces true
    /// zeros) — the sparsity certificate.
    pub nnz: usize,
}

/// Statistics of the per-outer-iteration Gram condition numbers
/// (Figures 4i–l / 7i–l report min / median / max over iterations).
#[derive(Clone, Copy, Debug, Default)]
pub struct CondStats {
    pub min: f64,
    pub median: f64,
    pub max: f64,
    pub count: usize,
}

impl CondStats {
    pub fn from_samples(mut samples: Vec<f64>) -> CondStats {
        samples.retain(|v| v.is_finite());
        if samples.is_empty() {
            return CondStats::default();
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        CondStats {
            min: samples[0],
            median: samples[samples.len() / 2],
            max: *samples.last().unwrap(),
            count: samples.len(),
        }
    }
}

/// Full trajectory + communication accounting of one solver run.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub records: Vec<IterRecord>,
    /// Prox-solver certificates (penalized objective, duality gap,
    /// subgradient residual, nnz) — populated instead of `records` by the
    /// CA-Prox solvers ([`crate::prox`]).
    pub prox: Vec<ProxRecord>,
    /// Gram condition number per outer iteration (if tracked).
    pub gram_conds: Vec<f64>,
    /// This rank's communication meter (solver traffic only — metric
    /// evaluation traffic is excluded by snapshot/restore).
    pub meter: CostMeter,
    /// Total inner iterations executed.
    pub iters: usize,
}

impl History {
    pub fn cond_stats(&self) -> CondStats {
        CondStats::from_samples(self.gram_conds.clone())
    }

    /// Heap allocations taken by this rank's communicator buffer pool
    /// during the solve — zero in steady state; a nonzero drift flags a
    /// regression in the zero-allocation collective hot path.
    pub fn pool_allocs(&self) -> u64 {
        self.meter.buf_allocs
    }

    /// First recorded iteration whose |objective error| ≤ tol.
    pub fn iters_to_obj_tol(&self, tol: f64) -> Option<usize> {
        self.records
            .iter()
            .find(|r| r.obj_err.abs() <= tol)
            .map(|r| r.iter)
    }

    /// Final |objective error|.
    pub fn final_obj_err(&self) -> f64 {
        self.records.last().map(|r| r.obj_err.abs()).unwrap_or(f64::NAN)
    }

    /// Final solution error.
    pub fn final_sol_err(&self) -> f64 {
        self.records.last().map(|r| r.sol_err).unwrap_or(f64::NAN)
    }

    /// Final duality gap of a prox run (NaN if none recorded).
    pub fn final_gap(&self) -> f64 {
        self.prox.last().map(|r| r.gap).unwrap_or(f64::NAN)
    }

    /// Final penalized objective of a prox run (NaN if none recorded).
    pub fn final_pen_obj(&self) -> f64 {
        self.prox.last().map(|r| r.pen_obj).unwrap_or(f64::NAN)
    }

    /// Final subgradient residual of a prox run (NaN if none recorded).
    pub fn final_subgrad(&self) -> f64 {
        self.prox.last().map(|r| r.subgrad).unwrap_or(f64::NAN)
    }

    /// Final iterate sparsity of a prox run (None if none recorded).
    pub fn final_nnz(&self) -> Option<usize> {
        self.prox.last().map(|r| r.nnz)
    }
}

/// Relative solution error.
pub fn relative_solution_error(w: &[f64], w_opt: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), w_opt.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (a, b) in w.iter().zip(w_opt) {
        num += (a - b) * (a - b);
        den += b * b;
    }
    (num / den.max(1e-300)).sqrt()
}

/// Relative objective error given precomputed objective values.
pub fn relative_objective_error(f_alg: f64, f_opt: f64) -> f64 {
    (f_alg - f_opt) / f_opt.abs().max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cond_stats_order() {
        let s = CondStats::from_samples(vec![3.0, 1.0, f64::INFINITY, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.count, 3);
    }

    #[test]
    fn solution_error_zero_for_exact() {
        let w = vec![1.0, -2.0, 3.0];
        assert_eq!(relative_solution_error(&w, &w), 0.0);
    }

    #[test]
    fn history_tol_search() {
        let h = History {
            records: vec![
                IterRecord { iter: 1, obj_err: 0.5, sol_err: 0.9 },
                IterRecord { iter: 10, obj_err: -0.05, sol_err: 0.4 },
                IterRecord { iter: 20, obj_err: 0.001, sol_err: 0.1 },
            ],
            ..Default::default()
        };
        assert_eq!(h.iters_to_obj_tol(0.1), Some(10));
        assert_eq!(h.iters_to_obj_tol(1e-6), None);
        assert_eq!(h.final_obj_err(), 0.001);
    }
}
