//! The shared s-step pipeline core: the [`CaStep`] method seam and the
//! [`drive`] outer loop that owns, exactly once, everything the six solver
//! loops used to duplicate — scratch-buffer hoisting, the collective
//! schedule (blocking and overlapped), condition tracking, the
//! `should_record` cadence, tolerance-based early stop, and the final
//! [`CostMeter`](crate::comm::CostMeter) snapshot.
//!
//! # The s-step shape
//!
//! Every CA method in this repo — BCD, BDCD, the Theorem-4 row-layout
//! BCD, CoCoA, and the CA-Prox pair — is the same outer iteration:
//!
//! 1. **sample**: draw this iteration's shared-seed coordinate blocks
//!    (zero communication, §3.1 of the paper);
//! 2. **local gram**: the sample-dependent (but *state-independent*) part
//!    of the collective payload — the packed Gram triangle;
//! 3. **local state**: the state-dependent payload tail (the residual
//!    `r`, the piggybacked `w` contribution, CoCoA's Δw);
//! 4. **one collective** (the method's only communication);
//! 5. **inner solve** on the reduced payload, replicated on every rank;
//! 6. **apply** the deferred updates.
//!
//! [`drive`] runs that loop under two schedules selected by
//! [`SolverOpts::overlap`]:
//!
//! * **blocking** — `allreduce_sum` between steps 3 and 5;
//! * **overlapped** — the payload reduces through the non-blocking
//!   `iallreduce_start`/`iallreduce_wait` pair while the rank computes.
//!   When the step's [`CaStep::prefetch_gram`] is true, the engine
//!   software-pipelines the *next* iteration's `local_gram` (legal
//!   because it never reads the evolving α/w state) under the in-flight
//!   reduction — the dominant flop cost hides the reduction latency.
//!   Steps whose gram is not prefetchable still get
//!   [`CaStep::hidden_work`] (overlap-tensor assembly, block gathers,
//!   CoCoA's dual-block commit) hidden under the in-flight collective.
//!
//! Both schedules issue the same collectives on the same payloads in the
//! same per-operation element order, so trajectories are **bitwise
//! identical** across schedules and to the pre-engine per-solver loops
//! (asserted against frozen copies of those loops in
//! `rust/tests/engine_equivalence.rs`).

use crate::comm::Communicator;
use crate::engine::checkpoint::{self, Checkpoint};
use crate::error::{Error, Result};
use crate::metrics::History;
use crate::solvers::common::{cond_stride, packed_gram_cond, should_record, SolverOpts};
use crate::telemetry;
use crate::trace::{self, OpClass, SpanKind};

/// One outer iteration's shared-seed sample: the `s` drawn blocks of `b`
/// coordinates plus their flattened kernel-order index list.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Outer-iteration index this sample belongs to (strictly increasing;
    /// under the prefetch schedule sample `k+1` is drawn while iteration
    /// `k`'s reduction is still in flight).
    pub k: usize,
    /// The `s` sampled blocks, each `b` distinct coordinate indices.
    pub blocks: Vec<Vec<usize>>,
    /// The blocks flattened into the contiguous layout every
    /// [`crate::gram::ComputeBackend`] kernel consumes.
    pub idx: Vec<usize>,
}

impl Sample {
    /// Build a sample from drawn blocks, flattening them into `idx`.
    pub fn flatten(k: usize, blocks: Vec<Vec<usize>>, b: usize) -> Sample {
        let mut idx = vec![0usize; blocks.len() * b];
        crate::solvers::common::flatten_blocks(&blocks, b, &mut idx);
        Sample { k, blocks, idx }
    }

    /// An empty sample for methods that do not draw shared-seed blocks
    /// (CoCoA samples rank-locally inside its local phase).
    pub fn empty(k: usize) -> Sample {
        Sample {
            k,
            blocks: Vec::new(),
            idx: Vec::new(),
        }
    }
}

/// One CA method's per-iteration callbacks, driven by [`drive`].
///
/// The engine owns the outer loop, the payload buffer (`[gram | state]`,
/// hoisted once in blocking mode, pooled ping-pong under the prefetch
/// schedule), the collective, condition tracking, record cadence, and
/// early stop; the step owns the method's math and iterate state.
///
/// Contract for bitwise schedule-equivalence (every implementor must
/// uphold it; the engine relies on it to reorder work across schedules):
///
/// * [`CaStep::sample`] is called exactly once per outer iteration, in
///   increasing `k` order, but possibly *before* iteration `k−1` has
///   applied its update — it must not read iterate state.
/// * [`CaStep::local_gram`] must be a pure function of the data shard and
///   the sample when [`CaStep::prefetch_gram`] is true (the engine then
///   calls it one iteration ahead, under the in-flight reduction).
/// * [`CaStep::local_state`] and [`CaStep::apply`] run strictly in
///   iteration order.
/// * [`CaStep::hidden_work`] must not depend on the reduced payload (it
///   runs while the collective is in flight under the overlap schedules)
///   and must not touch state that `local_gram` reads.
pub trait CaStep<C: Communicator> {
    /// `(gram_words, state_words)` split of the collective payload; the
    /// engine allocates `gram_words + state_words` and passes the two
    /// disjoint slices to [`CaStep::local_gram`] / [`CaStep::local_state`].
    fn payload_split(&self) -> (usize, usize);

    /// True when [`CaStep::local_gram`] depends only on the data shard and
    /// the shared-seed sample stream — the overlap schedule then
    /// prefetches the next iteration's gram under the in-flight reduction.
    fn prefetch_gram(&self) -> bool {
        false
    }

    /// Draw outer iteration `k`'s sample. `comm` is available so layouts
    /// that redistribute sampled data (the Theorem-4 all-to-all) can post
    /// their exchange as soon as the sample exists.
    fn sample(&mut self, comm: &mut C, k: usize) -> Result<Sample>;

    /// Fill the sample-dependent payload head (the packed Gram triangle).
    fn local_gram(&mut self, comm: &mut C, smp: &Sample, head: &mut [f64]) -> Result<()>;

    /// Fill the state-dependent payload tail (residual / `w` piggyback /
    /// Δw) immediately before the collective.
    fn local_state(&mut self, smp: &Sample, tail: &mut [f64]) -> Result<()>;

    /// Fill the whole payload in one shot — the hook the blocking and
    /// non-prefetch overlap schedules use (gram and state are produced
    /// for the *same* iteration there, so a backend's fused
    /// Gram+residual kernel can serve both in one pass; the XLA backend
    /// executes one artifact instead of two). The prefetch schedule
    /// cannot use it (gram is computed one iteration ahead) and calls
    /// the split methods instead. Must produce bitwise-identical
    /// payloads to `local_gram` + `local_state`.
    fn local_payload(
        &mut self,
        comm: &mut C,
        smp: &Sample,
        head: &mut [f64],
        tail: &mut [f64],
    ) -> Result<()> {
        self.local_gram(comm, smp, head)?;
        self.local_state(smp, tail)
    }

    /// Sample-only work the overlap schedules hide under the in-flight
    /// collective (overlap-tensor assembly, iterate block gathers); the
    /// blocking schedule runs it between the collective and the solve.
    fn hidden_work(&mut self, smp: &Sample) -> Result<()>;

    /// `(scale, shift)` of the Gram conditioning probe
    /// `scale·G + shift·I` ([`SolverOpts::track_gram_cond`]), or `None`
    /// when the method does not track conditioning.
    fn cond_probe(&self) -> Option<(f64, f64)> {
        None
    }

    /// Replicated inner solve on the reduced payload; returns the flat
    /// `s·b` update vector. Returning an **empty** vector means the solve
    /// is the identity — the engine then passes the reduced payload tail
    /// straight to [`CaStep::apply`] (CoCoA's Δw combine takes this
    /// zero-copy path).
    fn inner_solve(&mut self, smp: &Sample, head: &[f64], tail: &[f64]) -> Result<Vec<f64>>;

    /// Apply the deferred updates to the iterate state.
    fn apply(&mut self, smp: &Sample, deltas: &[f64]) -> Result<()>;

    /// Record convergence metrics at inner-iteration `h_now` (0 = before
    /// the first iteration). Metric communication must be meter-excluded
    /// (see [`crate::solvers::common::metered_out`]).
    fn record(&mut self, comm: &mut C, history: &mut History, h_now: usize) -> Result<()>;

    /// Whether the latest record satisfies the early-stop tolerance.
    fn converged(&self, history: &History, tol: f64) -> bool {
        let _ = (history, tol);
        false
    }

    /// Drain any method-internal in-flight operations (e.g. the row
    /// layout's look-ahead all-to-all) — called once after the outer loop,
    /// including after a tolerance-triggered early stop.
    fn flush(&mut self, comm: &mut C) -> Result<()> {
        let _ = comm;
        Ok(())
    }

    /// Stable tag identifying this step's checkpoint layout, written into
    /// every [`Checkpoint`] and validated at resume so a snapshot from one
    /// method cannot restore another. The default marks the step as not
    /// checkpointable.
    fn ckpt_kind(&self) -> &'static str {
        "unsupported"
    }

    /// Serialize the step's full mutable state — sampler RNG words plus
    /// every evolving iterate segment — into `ckpt`. Scratch that is
    /// recomputed from scratch each outer iteration must **not** be
    /// saved. Override together with [`CaStep::restore_state`].
    fn save_state(&self, ckpt: &mut Checkpoint) -> Result<()> {
        let _ = ckpt;
        Err(Error::Runtime(
            "this method does not support checkpointing".into(),
        ))
    }

    /// Restore the step's mutable state from a [`Checkpoint`] produced by
    /// [`CaStep::save_state`] on the same method and geometry. After this
    /// call the step must be bitwise-indistinguishable from one that ran
    /// iterations `0..ckpt.next_k` live.
    fn restore_state(&mut self, ckpt: &Checkpoint) -> Result<()> {
        let _ = ckpt;
        Err(Error::Runtime(
            "this method does not support checkpointing".into(),
        ))
    }
}

/// Snapshot the full solver state after completing outer iteration `k`
/// and hand it to the installed [`checkpoint`] sink. Runs only on the
/// non-prefetch schedules (capture is a clean boundary there: every
/// collective of iterations `0..=k` has completed, none of `k+1`'s has
/// started).
fn capture<C: Communicator, S: CaStep<C> + ?Sized>(
    step: &S,
    comm: &C,
    history: &History,
    k: usize,
) -> Result<()> {
    let mut ckpt = Checkpoint {
        kind: step.ckpt_kind().to_string(),
        rank: comm.rank() as u32,
        ranks: comm.size() as u32,
        next_k: (k + 1) as u64,
        iters: history.iters as u64,
        records: history.records.clone(),
        prox: history.prox.clone(),
        gram_conds: history.gram_conds.clone(),
        meter: *comm.meter(),
        ..Checkpoint::default()
    };
    let u0 = telemetry::now();
    step.save_state(&mut ckpt)?;
    checkpoint::store(&ckpt)?;
    telemetry::observe_since(telemetry::Hist::CkptSaveNs, u0);
    telemetry::count(telemetry::Counter::CkptSaves, 1);
    Ok(())
}

/// Gram conditioning sampler owned by [`drive`]: probe parameters, the
/// sampling stride, and the mirror scratch, bundled so the per-iteration
/// check stays one call.
struct CondTracker {
    probe: Option<(f64, f64)>,
    stride: usize,
    sb: usize,
    scratch: Vec<f64>,
}

impl CondTracker {
    fn new<C: Communicator, S: CaStep<C> + ?Sized>(
        step: &S,
        opts: &SolverOpts,
        sb: usize,
        outer: usize,
    ) -> CondTracker {
        let probe = if opts.track_gram_cond {
            step.cond_probe()
        } else {
            None
        };
        CondTracker {
            scratch: if probe.is_some() {
                vec![0.0; sb * sb]
            } else {
                Vec::new()
            },
            stride: cond_stride(sb, outer),
            sb,
            probe,
        }
    }

    /// Push the conditioning sample for outer iteration `k` if due.
    fn check(&mut self, history: &mut History, k: usize, buf: &[f64]) {
        if let Some((scale, shift)) = self.probe {
            if k % self.stride == 0 {
                history.gram_conds.push(packed_gram_cond(
                    buf,
                    self.sb,
                    scale,
                    shift,
                    &mut self.scratch,
                ));
            }
        }
    }
}

/// Replicated solve + deferred update on the reduced payload. An empty
/// `inner_solve` result is the identity solve: the reduced state tail is
/// applied directly (no copy).
fn solve_apply<C: Communicator, S: CaStep<C> + ?Sized>(
    step: &mut S,
    smp: &Sample,
    buf: &[f64],
    head: usize,
) -> Result<()> {
    let k = smp.k as u64;
    let t0 = trace::now();
    let u0 = telemetry::now();
    let deltas = step.inner_solve(smp, &buf[..head], &buf[head..])?;
    trace::record(SpanKind::InnerSolve, OpClass::Compute, k, buf.len() as u64, t0);
    telemetry::observe_since(telemetry::Hist::InnerSolveNs, u0);
    let t0 = trace::now();
    let u0 = telemetry::now();
    let res = if deltas.is_empty() {
        step.apply(smp, &buf[head..])
    } else {
        step.apply(smp, &deltas)
    };
    trace::record(SpanKind::Apply, OpClass::Compute, k, (buf.len() - head) as u64, t0);
    telemetry::observe_since(telemetry::Hist::ApplyNs, u0);
    res
}

/// Outer-boundary bookkeeping: advance `history.iters`, record on the
/// shared cadence, and report whether the tolerance stop fired.
fn boundary<C: Communicator, S: CaStep<C> + ?Sized>(
    step: &mut S,
    opts: &SolverOpts,
    comm: &mut C,
    history: &mut History,
    k: usize,
    outer: usize,
) -> Result<bool> {
    let h_now = (k + 1) * opts.s;
    history.iters = h_now;
    telemetry::count(telemetry::Counter::Outers, 1);
    telemetry::count(telemetry::Counter::Inners, opts.s as u64);
    telemetry::gauge(telemetry::Gauge::LastOuter, (k + 1) as u64);
    telemetry::gauge(telemetry::Gauge::LastH, h_now as u64);
    if should_record(h_now, opts.s, opts) || k + 1 == outer {
        let t0 = trace::now();
        step.record(comm, history, h_now)?;
        trace::record(SpanKind::Record, OpClass::Compute, h_now as u64, 0, t0);
        telemetry::count(telemetry::Counter::Records, 1);
        // Cross-rank health rollup, same cadence as the record (the
        // enabled check inside is rank-identical, so the aggregation
        // collective stays in lockstep; its traffic is meter-excluded,
        // trace-paused, and telemetry-paused).
        telemetry::aggregate_snapshot(
            comm,
            (k + 1) as u64,
            h_now as u64,
            telemetry::aggregate::last_cert(history),
        )?;
        if let Some(tol) = opts.tol {
            if step.converged(history, tol) {
                return Ok(true);
            }
        }
    }
    Ok(false)
}

/// Run one CA method's outer loop end to end: the single implementation
/// of the s-step schedule shared by all six solver loops (see the module
/// docs for the schedule definitions and the bitwise-equivalence
/// contract). On return, `history` holds the trajectory and this rank's
/// solver-traffic [`CostMeter`](crate::comm::CostMeter) snapshot.
pub fn drive<C: Communicator, S: CaStep<C> + ?Sized>(
    step: &mut S,
    opts: &SolverOpts,
    comm: &mut C,
    history: &mut History,
) -> Result<()> {
    let (head, tail) = step.payload_split();
    let total = head + tail;
    let outer = opts.outer_iters();
    let sb = opts.s * opts.b;
    let mut cond = CondTracker::new::<C, S>(&*step, opts, sb, outer);

    // Staged resume (`Session::resume`): restore the step's iterate
    // state, the recorded history, and this rank's meter, then continue
    // from the checkpoint's `next_k`. `ckpt_on` must be latched *before*
    // the staged checkpoint is consumed — it selects the non-prefetch
    // schedules (see the `checkpoint` module docs).
    let ckpt_on = checkpoint::active();
    let resumed = checkpoint::take_staged();
    let k0 = match &resumed {
        Some(ckpt) => {
            if ckpt.kind != step.ckpt_kind() {
                return Err(Error::Runtime(format!(
                    "checkpoint kind {:?} cannot resume a {:?} run",
                    ckpt.kind,
                    step.ckpt_kind()
                )));
            }
            if ckpt.ranks as usize != comm.size() || ckpt.rank as usize != comm.rank() {
                return Err(Error::Runtime(format!(
                    "checkpoint from rank {} of {} cannot resume rank {} of {}",
                    ckpt.rank,
                    ckpt.ranks,
                    comm.rank(),
                    comm.size()
                )));
            }
            let u0 = telemetry::now();
            step.restore_state(ckpt)?;
            ckpt.restore_history(history);
            *comm.meter_mut() = ckpt.meter;
            telemetry::observe_since(telemetry::Hist::CkptRestoreNs, u0);
            telemetry::count(telemetry::Counter::CkptRestores, 1);
            ckpt.next_k as usize
        }
        None => 0,
    };

    if resumed.is_none() {
        let t0 = trace::now();
        step.record(comm, history, 0)?;
        trace::record(SpanKind::Record, OpClass::Compute, 0, 0, t0);
        telemetry::count(telemetry::Counter::Records, 1);
    }

    if opts.overlap && step.prefetch_gram() && outer > 0 && !ckpt_on {
        // Prefetch schedule. Pipeline prologue: gram 0 is computed before
        // the loop; thereafter gram k+1 is computed under the in-flight
        // reduction of [gram_k | state_k]. Payload buffers ping-pong
        // through the communicator's rank-local pool.
        let t0 = trace::now();
        let u0 = telemetry::now();
        let mut smp_cur = step.sample(comm, 0)?;
        trace::record(SpanKind::Sample, OpClass::Compute, 0, 0, t0);
        telemetry::observe_since(telemetry::Hist::SampleNs, u0);
        let mut next_buf = comm.take_buf(total);
        let t0 = trace::now();
        let u0 = telemetry::now();
        step.local_gram(comm, &smp_cur, &mut next_buf[..head])?;
        trace::record(SpanKind::GramLocal, OpClass::Compute, 0, head as u64, t0);
        telemetry::observe_since(telemetry::Hist::GramNs, u0);
        'outer_loop: for k in 0..outer {
            let mut buf = std::mem::take(&mut next_buf); // holds gram_k
            let t0 = trace::now();
            let u0 = telemetry::now();
            step.local_state(&smp_cur, &mut buf[head..])?;
            trace::record(SpanKind::GramLocal, OpClass::Compute, k as u64, tail as u64, t0);
            telemetry::observe_since(telemetry::Hist::GramNs, u0);

            // THE communication of this outer iteration — non-blocking.
            let handle = comm.iallreduce_start(buf)?;
            let u_win = telemetry::now();

            // ---- local work hidden behind the in-flight reduction ------
            // The prefetched GramLocal span below lands inside the
            // in-flight window [start, wait] — exactly what the overlap-
            // efficiency analysis measures.
            let mut pending: Option<Sample> = None;
            if k + 1 < outer {
                let t0 = trace::now();
                let u0 = telemetry::now();
                let nxt = step.sample(comm, k + 1)?;
                trace::record(SpanKind::Sample, OpClass::Compute, (k + 1) as u64, 0, t0);
                telemetry::observe_since(telemetry::Hist::SampleNs, u0);
                next_buf = comm.take_buf(total);
                let t0 = trace::now();
                let u0 = telemetry::now();
                step.local_gram(comm, &nxt, &mut next_buf[..head])?;
                trace::record(SpanKind::GramLocal, OpClass::Compute, (k + 1) as u64, head as u64, t0);
                telemetry::observe_since(telemetry::Hist::GramNs, u0);
                pending = Some(nxt);
            }
            step.hidden_work(&smp_cur)?;
            // ------------------------------------------------------------
            telemetry::gauge(
                telemetry::Gauge::InflightNs,
                telemetry::now().saturating_sub(u_win),
            );
            let buf = comm.iallreduce_wait(handle)?;

            cond.check(history, k, &buf);
            solve_apply::<C, S>(step, &smp_cur, &buf, head)?;
            comm.give_buf(buf);

            if let Some(nxt) = pending {
                smp_cur = nxt; // rotate the pipeline
            }
            if boundary(step, opts, comm, history, k, outer)? {
                break 'outer_loop;
            }
        }
        if !next_buf.is_empty() {
            // Early stop left a prefetched gram in flight-side storage.
            comm.give_buf(next_buf);
        }
    } else if opts.overlap {
        // Non-prefetch overlap: the payload is produced in iteration
        // order, but the reduction is non-blocking with `hidden_work`
        // running under it.
        let mut buf = vec![0.0; total];
        'outer_loop2: for k in k0..outer {
            let t0 = trace::now();
            let u0 = telemetry::now();
            let smp = step.sample(comm, k)?;
            trace::record(SpanKind::Sample, OpClass::Compute, k as u64, 0, t0);
            telemetry::observe_since(telemetry::Hist::SampleNs, u0);
            {
                let t0 = trace::now();
                let u0 = telemetry::now();
                let (h, t) = buf.split_at_mut(head);
                step.local_payload(comm, &smp, h, t)?;
                trace::record(SpanKind::GramLocal, OpClass::Compute, k as u64, total as u64, t0);
                telemetry::observe_since(telemetry::Hist::GramNs, u0);
            }
            // Move the hoisted buffer into the handle and take it back
            // reduced — no payload copies on the hot path.
            let handle = comm.iallreduce_start(std::mem::take(&mut buf))?;
            let u_win = telemetry::now();
            step.hidden_work(&smp)?;
            telemetry::gauge(
                telemetry::Gauge::InflightNs,
                telemetry::now().saturating_sub(u_win),
            );
            buf = comm.iallreduce_wait(handle)?;

            cond.check(history, k, &buf);
            solve_apply::<C, S>(step, &smp, &buf, head)?;

            if boundary(step, opts, comm, history, k, outer)? {
                break 'outer_loop2;
            }
            if checkpoint::capture_due(k) {
                capture::<C, S>(step, comm, history, k)?;
            }
        }
    } else {
        // Blocking schedule: one hoisted payload buffer, `allreduce_sum`,
        // hidden work between the collective and the solve.
        let mut buf = vec![0.0; total];
        'outer_loop3: for k in k0..outer {
            let t0 = trace::now();
            let u0 = telemetry::now();
            let smp = step.sample(comm, k)?;
            trace::record(SpanKind::Sample, OpClass::Compute, k as u64, 0, t0);
            telemetry::observe_since(telemetry::Hist::SampleNs, u0);
            {
                let t0 = trace::now();
                let u0 = telemetry::now();
                let (h, t) = buf.split_at_mut(head);
                step.local_payload(comm, &smp, h, t)?;
                trace::record(SpanKind::GramLocal, OpClass::Compute, k as u64, total as u64, t0);
                telemetry::observe_since(telemetry::Hist::GramNs, u0);
            }

            // THE communication of this outer iteration.
            comm.allreduce_sum(&mut buf)?;

            cond.check(history, k, &buf);
            step.hidden_work(&smp)?;
            solve_apply::<C, S>(step, &smp, &buf, head)?;

            if boundary(step, opts, comm, history, k, outer)? {
                break 'outer_loop3;
            }
            if checkpoint::capture_due(k) {
                capture::<C, S>(step, comm, history, k)?;
            }
        }
    }

    step.flush(comm)?;
    history.meter = *comm.meter();
    Ok(())
}
