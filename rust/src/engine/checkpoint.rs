//! Bitwise-exact s-step checkpoint/restart.
//!
//! The s-step structure makes solver state at an outer boundary *tiny*:
//! the iterate vectors, the sampler's four RNG words (the scratch
//! permutation is identity between draws), the recorded history, and
//! this rank's [`CostMeter`]. [`crate::engine::drive`] snapshots exactly
//! that every `every`-th outer iteration through a [`CheckpointSink`],
//! and [`Session::resume`](crate::engine::Session::resume) replays the
//! remaining iterations — **bitwise-equal** to an uninterrupted run with
//! the same checkpoint cadence, for every method under both schedules
//! (asserted by `rust/tests/chaos.rs`).
//!
//! # Capture semantics
//!
//! A checkpoint taken at outer iteration `k` holds the state *after*
//! `apply(k)` and `boundary(k)`: sampler RNG after draws `0..=k`, the
//! iterate after update `k`, history through `h = (k+1)·s`, and the
//! meter after every collective of iterations `0..=k`. `next_k = k+1`
//! is the first iteration the resumed run executes.
//!
//! While checkpointing (or a staged resume) is active, the engine runs
//! the **non-prefetch** schedules: the cross-iteration Gram prefetch
//! (and `bcd_row`'s look-ahead all-to-all) would leave iteration `k+1`'s
//! collectives in flight at the capture point, so capture serializes the
//! pipeline instead of trying to attribute cross-iteration traffic.
//! Collective and word counts are schedule-invariant (the
//! `engine_equivalence` suite pins this), only the overlap window
//! shrinks. With checkpointing **off** nothing changes — the enable
//! check is two thread-local reads, and the 48 pinned engine configs
//! stay bitwise/event-identical.
//!
//! The meter is restored wholesale at resume, with one caveat:
//! [`CostMeter::buf_allocs`] counts pool warmup, and a resumed run
//! re-warms its fresh communicator pool, so that one field may exceed
//! the uninterrupted run's count. All wire counts (messages, words,
//! collectives, waits) are exact.
//!
//! # Wire format
//!
//! [`Checkpoint::to_bytes`] is a little-endian, versioned, stdlib-only
//! layout: magic `CABCDCKP`, format version, method tag, rank geometry,
//! `next_k`, RNG words, named `f64`/`u64` state segments, history
//! records, meter. [`Checkpoint::state_words`] (the machine-independent
//! size of the solver state proper) is gated in `BENCH_hotpath.json`.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::comm::CostMeter;
use crate::error::{Error, Result};
use crate::metrics::{History, IterRecord, ProxRecord};

/// Format version written into every serialized checkpoint. Bump on any
/// layout change; [`Checkpoint::from_bytes`] rejects other versions.
pub const CHECKPOINT_VERSION: u32 = 1;

/// Magic prefix of a serialized checkpoint.
pub const CHECKPOINT_MAGIC: &[u8; 8] = b"CABCDCKP";

/// One rank's full solver snapshot at an outer-iteration boundary.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    /// Step-kind tag ([`crate::engine::CaStep::ckpt_kind`]) — validated
    /// at restore so a BDCD checkpoint cannot resume a BCD run.
    pub kind: String,
    /// Owning rank.
    pub rank: u32,
    /// Group size the snapshot was taken under.
    pub ranks: u32,
    /// First outer iteration the resumed run executes.
    pub next_k: u64,
    /// `History::iters` at capture.
    pub iters: u64,
    /// Sampler RNG words (empty for sampler-less steps).
    pub rng: Vec<u64>,
    /// Named `f64` state segments (iterates, residuals) in a fixed
    /// per-method order.
    pub seg_f64: Vec<(String, Vec<f64>)>,
    /// Named `u64` state segments (e.g. `bcd_row`'s per-iteration load
    /// maxima).
    pub seg_u64: Vec<(String, Vec<u64>)>,
    /// Smooth-solver records at capture.
    pub records: Vec<IterRecord>,
    /// Prox certificates at capture.
    pub prox: Vec<ProxRecord>,
    /// Gram conditioning samples at capture.
    pub gram_conds: Vec<f64>,
    /// This rank's meter after every collective of iterations `0..next_k`.
    pub meter: CostMeter,
}

impl Checkpoint {
    /// Append a named `f64` segment (save-hook helper).
    pub fn push_f64(&mut self, name: &str, data: &[f64]) {
        self.seg_f64.push((name.to_string(), data.to_vec()));
    }

    /// Append a named `u64` segment.
    pub fn push_u64(&mut self, name: &str, data: &[u64]) {
        self.seg_u64.push((name.to_string(), data.to_vec()));
    }

    /// Fetch a named `f64` segment (restore-hook helper).
    pub fn get_f64(&self, name: &str) -> Result<&[f64]> {
        self.seg_f64
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| d.as_slice())
            .ok_or_else(|| Error::Runtime(format!("checkpoint missing f64 segment {name:?}")))
    }

    /// The four xoshiro words of the sampler RNG (restore-hook helper for
    /// the shared-seed steps, which all store exactly one sampler state).
    pub fn rng_words(&self) -> Result<[u64; 4]> {
        if self.rng.len() != 4 {
            return Err(Error::Runtime(format!(
                "checkpoint: {} RNG words, expected 4",
                self.rng.len()
            )));
        }
        Ok([self.rng[0], self.rng[1], self.rng[2], self.rng[3]])
    }

    /// Fetch a named `u64` segment.
    pub fn get_u64(&self, name: &str) -> Result<&[u64]> {
        self.seg_u64
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| d.as_slice())
            .ok_or_else(|| Error::Runtime(format!("checkpoint missing u64 segment {name:?}")))
    }

    /// Copy a named `f64` segment into an existing buffer of the same
    /// length (the common restore path).
    pub fn read_f64_into(&self, name: &str, out: &mut [f64]) -> Result<()> {
        let seg = self.get_f64(name)?;
        if seg.len() != out.len() {
            return Err(Error::Runtime(format!(
                "checkpoint segment {name:?}: {} words, expected {}",
                seg.len(),
                out.len()
            )));
        }
        out.copy_from_slice(seg);
        Ok(())
    }

    /// 64-bit words of solver state proper (RNG + named segments) — the
    /// machine-independent size gated by the hot-path bench. History and
    /// meter are bookkeeping, not solver state, and scale with the record
    /// cadence rather than the method.
    pub fn state_words(&self) -> usize {
        self.rng.len()
            + self.seg_f64.iter().map(|(_, d)| d.len()).sum::<usize>()
            + self.seg_u64.iter().map(|(_, d)| d.len()).sum::<usize>()
    }

    /// Serialize (little-endian, versioned; see the module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(128 + 8 * self.state_words());
        out.extend_from_slice(CHECKPOINT_MAGIC);
        put_u32(&mut out, CHECKPOINT_VERSION);
        put_str(&mut out, &self.kind);
        put_u32(&mut out, self.rank);
        put_u32(&mut out, self.ranks);
        put_u64(&mut out, self.next_k);
        put_u64(&mut out, self.iters);
        put_u32(&mut out, self.rng.len() as u32);
        for &w in &self.rng {
            put_u64(&mut out, w);
        }
        put_u32(&mut out, self.seg_f64.len() as u32);
        for (name, data) in &self.seg_f64 {
            put_str(&mut out, name);
            put_u64(&mut out, data.len() as u64);
            for &v in data {
                put_f64(&mut out, v);
            }
        }
        put_u32(&mut out, self.seg_u64.len() as u32);
        for (name, data) in &self.seg_u64 {
            put_str(&mut out, name);
            put_u64(&mut out, data.len() as u64);
            for &v in data {
                put_u64(&mut out, v);
            }
        }
        put_u32(&mut out, self.records.len() as u32);
        for r in &self.records {
            put_u64(&mut out, r.iter as u64);
            put_f64(&mut out, r.obj_err);
            put_f64(&mut out, r.sol_err);
        }
        put_u32(&mut out, self.prox.len() as u32);
        for r in &self.prox {
            put_u64(&mut out, r.iter as u64);
            put_f64(&mut out, r.pen_obj);
            put_f64(&mut out, r.gap);
            put_f64(&mut out, r.subgrad);
            put_u64(&mut out, r.nnz as u64);
        }
        put_u32(&mut out, self.gram_conds.len() as u32);
        for &v in &self.gram_conds {
            put_f64(&mut out, v);
        }
        for v in [
            self.meter.msgs,
            self.meter.words,
            self.meter.recv_msgs,
            self.meter.recv_words,
            self.meter.allreduces,
            self.meter.all_to_alls,
            self.meter.collective_waits,
            self.meter.buf_allocs,
            self.meter.retries,
            self.meter.timeouts,
        ] {
            put_u64(&mut out, v);
        }
        out
    }

    /// Deserialize a [`Checkpoint::to_bytes`] blob, validating magic and
    /// version.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let mut rd = Reader { buf: bytes, pos: 0 };
        let magic = rd.bytes(8)?;
        if magic != CHECKPOINT_MAGIC {
            return Err(Error::Runtime("checkpoint: bad magic".into()));
        }
        let version = rd.u32()?;
        if version != CHECKPOINT_VERSION {
            return Err(Error::Runtime(format!(
                "checkpoint: format version {version} (this build reads {CHECKPOINT_VERSION})"
            )));
        }
        let kind = rd.string()?;
        let rank = rd.u32()?;
        let ranks = rd.u32()?;
        let next_k = rd.u64()?;
        let iters = rd.u64()?;
        let nrng = rd.u32()? as usize;
        let mut rng = Vec::with_capacity(nrng);
        for _ in 0..nrng {
            rng.push(rd.u64()?);
        }
        let nf = rd.u32()? as usize;
        let mut seg_f64 = Vec::with_capacity(nf);
        for _ in 0..nf {
            let name = rd.string()?;
            let len = rd.u64()? as usize;
            let mut data = Vec::with_capacity(len);
            for _ in 0..len {
                data.push(rd.f64()?);
            }
            seg_f64.push((name, data));
        }
        let nu = rd.u32()? as usize;
        let mut seg_u64 = Vec::with_capacity(nu);
        for _ in 0..nu {
            let name = rd.string()?;
            let len = rd.u64()? as usize;
            let mut data = Vec::with_capacity(len);
            for _ in 0..len {
                data.push(rd.u64()?);
            }
            seg_u64.push((name, data));
        }
        let nr = rd.u32()? as usize;
        let mut records = Vec::with_capacity(nr);
        for _ in 0..nr {
            records.push(IterRecord {
                iter: rd.u64()? as usize,
                obj_err: rd.f64()?,
                sol_err: rd.f64()?,
            });
        }
        let np = rd.u32()? as usize;
        let mut prox = Vec::with_capacity(np);
        for _ in 0..np {
            prox.push(ProxRecord {
                iter: rd.u64()? as usize,
                pen_obj: rd.f64()?,
                gap: rd.f64()?,
                subgrad: rd.f64()?,
                nnz: rd.u64()? as usize,
            });
        }
        let ng = rd.u32()? as usize;
        let mut gram_conds = Vec::with_capacity(ng);
        for _ in 0..ng {
            gram_conds.push(rd.f64()?);
        }
        let meter = CostMeter {
            msgs: rd.u64()?,
            words: rd.u64()?,
            recv_msgs: rd.u64()?,
            recv_words: rd.u64()?,
            allreduces: rd.u64()?,
            all_to_alls: rd.u64()?,
            collective_waits: rd.u64()?,
            buf_allocs: rd.u64()?,
            retries: rd.u64()?,
            timeouts: rd.u64()?,
        };
        Ok(Checkpoint {
            kind,
            rank,
            ranks,
            next_k,
            iters,
            rng,
            seg_f64,
            seg_u64,
            records,
            prox,
            gram_conds,
            meter,
        })
    }

    /// Restore this checkpoint's history bookkeeping into `history`
    /// (engine resume path).
    pub(crate) fn restore_history(&self, history: &mut History) {
        history.records = self.records.clone();
        history.prox = self.prox.clone();
        history.gram_conds = self.gram_conds.clone();
        history.iters = self.iters as usize;
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn bytes(&mut self, n: usize) -> Result<&[u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Runtime(format!(
                "checkpoint: truncated at byte {} (wanted {n} more of {})",
                self.pos,
                self.buf.len()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let b = self.bytes(len)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| Error::Runtime("checkpoint: non-UTF8 name".into()))
    }
}

/// Where captured checkpoints go. Each rank thread installs its own sink
/// (a [`MemorySink`] clone sharing one store, or a [`FileSink`] writing
/// per-rank files).
pub trait CheckpointSink {
    /// Persist `ckpt` as the latest snapshot for `ckpt.rank` (previous
    /// snapshots for the rank may be overwritten).
    fn store(&mut self, ckpt: &Checkpoint) -> Result<()>;

    /// Human-readable location of `rank`'s latest snapshot (driver
    /// reports name it so an aborted run's notes say what to resume from).
    fn describe(&self, rank: usize) -> String;
}

/// In-memory sink: clones share one store, so P rank threads install P
/// clones and the test harness reads every rank's snapshot afterwards.
#[derive(Clone, Default)]
pub struct MemorySink {
    store: Arc<Mutex<HashMap<u32, Vec<u8>>>>,
}

impl MemorySink {
    /// A fresh, empty shared store.
    pub fn new() -> MemorySink {
        MemorySink::default()
    }

    /// Deserialize `rank`'s latest snapshot, if one was captured.
    pub fn load(&self, rank: usize) -> Result<Option<Checkpoint>> {
        let store = self
            .store
            .lock()
            .map_err(|_| Error::Runtime("checkpoint store poisoned".into()))?;
        match store.get(&(rank as u32)) {
            Some(bytes) => Checkpoint::from_bytes(bytes).map(Some),
            None => Ok(None),
        }
    }
}

impl CheckpointSink for MemorySink {
    fn store(&mut self, ckpt: &Checkpoint) -> Result<()> {
        let bytes = ckpt.to_bytes();
        let mut store = self
            .store
            .lock()
            .map_err(|_| Error::Runtime("checkpoint store poisoned".into()))?;
        store.insert(ckpt.rank, bytes);
        Ok(())
    }

    fn describe(&self, _rank: usize) -> String {
        "memory".to_string()
    }
}

/// File-backed sink: one file per rank under a directory, written whole
/// then renamed so readers never observe a torn snapshot.
#[derive(Clone, Debug)]
pub struct FileSink {
    dir: PathBuf,
}

impl FileSink {
    /// A sink writing `ckpt_r<rank>.bin` files under `dir` (created if
    /// missing).
    pub fn new(dir: impl Into<PathBuf>) -> Result<FileSink> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(FileSink { dir })
    }

    /// Path of `rank`'s snapshot file.
    pub fn rank_path(&self, rank: usize) -> PathBuf {
        self.dir.join(format!("ckpt_r{rank}.bin"))
    }

    /// Load and deserialize `rank`'s snapshot, if the file exists.
    pub fn load(&self, rank: usize) -> Result<Option<Checkpoint>> {
        let path = self.rank_path(rank);
        if !path.exists() {
            return Ok(None);
        }
        load_checkpoint_file(&path).map(Some)
    }
}

impl CheckpointSink for FileSink {
    fn store(&mut self, ckpt: &Checkpoint) -> Result<()> {
        let path = self.rank_path(ckpt.rank as usize);
        let tmp = self.dir.join(format!("ckpt_r{}.tmp", ckpt.rank));
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&ckpt.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    fn describe(&self, rank: usize) -> String {
        self.rank_path(rank).display().to_string()
    }
}

/// Read and deserialize one checkpoint file.
pub fn load_checkpoint_file(path: &Path) -> Result<Checkpoint> {
    let bytes = std::fs::read(path)?;
    Checkpoint::from_bytes(&bytes)
}

// ---- thread-local engine hookup (mirrors `trace`'s install/take) -------

struct CkptState {
    sink: Box<dyn CheckpointSink>,
    every: usize,
}

thread_local! {
    static STATE: RefCell<Option<CkptState>> = const { RefCell::new(None) };
    static ACTIVE: Cell<bool> = const { Cell::new(false) };
    static STAGED: RefCell<Option<Checkpoint>> = const { RefCell::new(None) };
    static STAGED_FLAG: Cell<bool> = const { Cell::new(false) };
}

/// Install a capture sink on the current thread (one per rank thread,
/// like [`crate::trace::install`]): subsequent [`crate::engine::drive`]
/// calls snapshot every `every`-th outer iteration. Replaces and returns
/// any previously installed sink.
pub fn install(sink: Box<dyn CheckpointSink>, every: usize) -> Option<Box<dyn CheckpointSink>> {
    ACTIVE.with(|a| a.set(every > 0));
    STATE.with(|s| {
        s.borrow_mut()
            .replace(CkptState { sink, every })
            .map(|st| st.sink)
    })
}

/// Remove and return the current thread's capture sink.
pub fn take() -> Option<Box<dyn CheckpointSink>> {
    ACTIVE.with(|a| a.set(false));
    STATE.with(|s| s.borrow_mut().take().map(|st| st.sink))
}

/// True when a capture sink is installed on this thread. Cost when off:
/// one thread-local read — the zero-overhead-when-disabled contract.
pub fn enabled() -> bool {
    ACTIVE.with(|a| a.get())
}

/// Stage a checkpoint for the next [`crate::engine::drive`] call on this
/// thread to resume from ([`crate::engine::Session::resume`] does this).
pub fn stage_resume(ckpt: Checkpoint) {
    STAGED_FLAG.with(|f| f.set(true));
    STAGED.with(|s| *s.borrow_mut() = Some(ckpt));
}

/// True when a staged resume is pending on this thread.
pub fn resume_staged() -> bool {
    STAGED_FLAG.with(|f| f.get())
}

/// True when checkpointing affects the engine schedule on this thread —
/// capture installed or a resume staged. The engine (and `bcd_row`'s
/// look-ahead pipeline) disable cross-iteration prefetch while active;
/// see the module docs.
pub fn active() -> bool {
    enabled() || resume_staged()
}

/// Consume the staged resume checkpoint, if any (engine entry).
pub(crate) fn take_staged() -> Option<Checkpoint> {
    STAGED_FLAG.with(|f| f.set(false));
    STAGED.with(|s| s.borrow_mut().take())
}

/// Whether the engine should capture after completing outer iteration
/// `k` (0-based): every `every`-th boundary.
pub(crate) fn capture_due(k: usize) -> bool {
    enabled()
        && STATE.with(|s| {
            s.borrow()
                .as_ref()
                .is_some_and(|st| st.every > 0 && (k + 1) % st.every == 0)
        })
}

/// Store a captured checkpoint through the installed sink.
pub(crate) fn store(ckpt: &Checkpoint) -> Result<()> {
    STATE.with(|s| match s.borrow_mut().as_mut() {
        Some(st) => st.sink.store(ckpt),
        None => Err(Error::Runtime(
            "checkpoint capture with no sink installed".into(),
        )),
    })
}

/// Location of this thread's latest snapshot for `rank`, if a sink is
/// installed (driver abort notes).
pub fn describe_sink(rank: usize) -> Option<String> {
    STATE.with(|s| s.borrow().as_ref().map(|st| st.sink.describe(rank)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_ckpt() -> Checkpoint {
        Checkpoint {
            kind: "bcd".into(),
            rank: 2,
            ranks: 4,
            next_k: 7,
            iters: 21,
            rng: vec![1, 2, 3, 4],
            seg_f64: vec![
                ("w".into(), vec![1.5, -2.25, 0.0]),
                ("alpha".into(), vec![f64::NAN, 1e-300]),
            ],
            seg_u64: vec![("max_loads".into(), vec![9, 8, 7])],
            records: vec![IterRecord {
                iter: 3,
                obj_err: -0.5,
                sol_err: 0.25,
            }],
            prox: vec![ProxRecord {
                iter: 3,
                pen_obj: 1.0,
                gap: f64::NAN,
                subgrad: 0.125,
                nnz: 5,
            }],
            gram_conds: vec![10.0, 20.0],
            meter: CostMeter {
                msgs: 1,
                words: 2,
                recv_msgs: 3,
                recv_words: 4,
                allreduces: 5,
                all_to_alls: 6,
                collective_waits: 7,
                buf_allocs: 8,
                retries: 9,
                timeouts: 10,
            },
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let c = sample_ckpt();
        let bytes = c.to_bytes();
        let d = Checkpoint::from_bytes(&bytes).unwrap();
        // Compare through re-serialization: covers every field,
        // including NaN payload bits.
        assert_eq!(bytes, d.to_bytes());
        assert_eq!(d.kind, "bcd");
        assert_eq!(d.next_k, 7);
        assert_eq!(d.get_f64("w").unwrap(), &[1.5, -2.25, 0.0]);
        assert_eq!(d.get_u64("max_loads").unwrap(), &[9, 8, 7]);
        assert_eq!(d.meter, c.meter);
        assert_eq!(d.state_words(), 4 + 5 + 3);
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let mut bytes = sample_ckpt().to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..6]).is_err(), "truncated");
        bytes[0] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err(), "magic");
        let mut bytes = sample_ckpt().to_bytes();
        bytes[8] = 99; // version LE byte 0
        let err = format!("{:?}", Checkpoint::from_bytes(&bytes).unwrap_err());
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn memory_sink_roundtrips_per_rank() {
        let sink = MemorySink::new();
        let mut s0 = sink.clone();
        let mut c = sample_ckpt();
        s0.store(&c).unwrap();
        c.rank = 3;
        c.next_k = 11;
        s0.store(&c).unwrap();
        let got = sink.load(2).unwrap().unwrap();
        assert_eq!(got.next_k, 7);
        let got3 = sink.load(3).unwrap().unwrap();
        assert_eq!(got3.next_k, 11);
        assert!(sink.load(0).unwrap().is_none());
    }

    #[test]
    fn file_sink_roundtrips() {
        let dir = std::env::temp_dir().join(format!("cabcd_ckpt_test_{}", std::process::id()));
        let mut sink = FileSink::new(&dir).unwrap();
        let c = sample_ckpt();
        sink.store(&c).unwrap();
        let got = sink.load(2).unwrap().unwrap();
        assert_eq!(got.to_bytes(), c.to_bytes());
        assert!(sink.describe(2).contains("ckpt_r2.bin"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn thread_local_install_take_and_cadence() {
        assert!(!enabled());
        assert!(!capture_due(0));
        install(Box::new(MemorySink::new()), 3);
        assert!(enabled());
        assert!(active());
        // every=3: capture after outer iterations 2, 5, 8, … (0-based).
        assert!(!capture_due(0));
        assert!(!capture_due(1));
        assert!(capture_due(2));
        assert!(capture_due(5));
        let _ = take();
        assert!(!enabled());
        assert!(!active());
    }

    #[test]
    fn staging_roundtrip() {
        assert!(!resume_staged());
        stage_resume(sample_ckpt());
        assert!(resume_staged());
        assert!(active());
        let got = take_staged().unwrap();
        assert_eq!(got.next_k, 7);
        assert!(!resume_staged());
        assert!(take_staged().is_none());
    }
}
