#![deny(missing_docs)]
//! Unified s-step solver engine: the [`Problem`]/[`Session`] API and the
//! shared pipeline core every CA method runs through.
//!
//! The paper's four methods (and the CA-Prox pair from arXiv:1712.06047)
//! are all the same s-step shape — shared-seed sample, local packed Gram,
//! one collective, redundant inner solve, deferred update. This module
//! owns that shape **once**:
//!
//! * [`Problem`] — what is being solved: the rank's data shard, labels,
//!   global dimensions, and the optional ridge ground-truth
//!   [`Reference`]. The regularizer rides in
//!   [`SolverOpts::reg`](crate::solvers::SolverOpts).
//! * [`Session`] — how to solve it: a builder binding a problem to
//!   [`SolverOpts`], a [`Method`], a
//!   [`ComputeBackend`](crate::gram::ComputeBackend), and a
//!   [`Communicator`]; [`Session::run`] dispatches to the method's
//!   [`CaStep`] and drives it through the one pipeline core
//!   ([`step::drive`]).
//! * [`Method`] — the parsed method selector (replaces the stringly
//!   `match cfg.solver.method.as_str()` driver dispatch; unknown strings
//!   fail at config load).
//! * [`CaStep`] — the per-method seam (`sample`, `local_gram`,
//!   `local_state`, `inner_solve`, `apply`, …); implemented by
//!   `solvers::{bcd, bdcd, bcd_row, cocoa}` and `prox::{bcd, bdcd}`.
//!
//! # Migration example
//!
//! The pre-engine free functions survive as thin wrappers, so this:
//!
//! ```ignore
//! let out = bcd::run(&x_loc, &y_loc, n, &opts, Some(&r), comm, be)?;
//! ```
//!
//! is now equivalent to:
//!
//! ```ignore
//! use cabcd::engine::{Method, Problem, Session};
//! let problem = Problem::primal(&x_loc, &y_loc, n).with_reference(Some(&r));
//! let out = Session::new(&problem)
//!     .opts(opts.clone())
//!     .method(Method::CaBcd)
//!     .backend(be)
//!     .comm(comm)
//!     .run()?
//!     .into_primal()?;
//! ```
//!
//! Every solver's trajectory and per-rank wire counts are bitwise
//! identical to the pre-engine per-solver loops (frozen copies of which
//! are asserted against in `rust/tests/engine_equivalence.rs`).

pub mod checkpoint;
pub mod step;

pub use checkpoint::{Checkpoint, CheckpointSink, FileSink, MemorySink};
pub use step::{drive, CaStep, Sample};

use crate::comm::Communicator;
use crate::error::{Error, Result};
use crate::gram::ComputeBackend;
use crate::matrix::Matrix;
use crate::metrics::{History, Reference};
use crate::prox::Regularizer;
use crate::solvers::cg::{self, CgOpts, CgOutput};
use crate::solvers::cocoa::{self, CocoaOpts, CocoaOutput};
use crate::solvers::{bcd, bcd_row, bdcd, DualOutput, PrimalOutput, SolverOpts};

/// Parsed solver-method selector — the driver dispatches on this enum
/// instead of matching raw config strings, so an unknown method fails at
/// config load, not deep inside the experiment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Classical primal BCD (Algorithm 1; the engine forces `s` to 1).
    Bcd,
    /// Communication-avoiding primal BCD (Algorithm 2).
    CaBcd,
    /// Classical dual BDCD (Algorithm 3; the engine forces `s` to 1).
    Bdcd,
    /// Communication-avoiding dual BDCD (Algorithm 4).
    CaBdcd,
    /// Primal BCD under the mismatched 1D-block-row layout (Theorem 4;
    /// the engine forces `s` to 1).
    BcdRow,
    /// CA primal BCD under the 1D-block-row layout (Theorem 8).
    CaBcdRow,
    /// The CoCoA-style local-solve + average baseline (§1 contrast).
    Cocoa,
    /// Conjugate gradients on the regularized normal equations (the
    /// Krylov baseline and ground-truth source).
    Cg,
}

impl Method {
    /// Parse a config-file method string; unknown strings error loudly.
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "bcd" => Method::Bcd,
            "cabcd" => Method::CaBcd,
            "bdcd" => Method::Bdcd,
            "cabdcd" => Method::CaBdcd,
            "bcdrow" => Method::BcdRow,
            "cabcdrow" => Method::CaBcdRow,
            "cocoa" => Method::Cocoa,
            "cg" => Method::Cg,
            other => {
                return Err(Error::Config(format!(
                    "unknown method {other:?} (want bcd|cabcd|bdcd|cabdcd|\
                     bcdrow|cabcdrow|cocoa|cg)"
                )))
            }
        })
    }

    /// Canonical config-file name.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Bcd => "bcd",
            Method::CaBcd => "cabcd",
            Method::Bdcd => "bdcd",
            Method::CaBdcd => "cabdcd",
            Method::BcdRow => "bcdrow",
            Method::CaBcdRow => "cabcdrow",
            Method::Cocoa => "cocoa",
            Method::Cg => "cg",
        }
    }

    /// Whether this is a communication-avoiding variant (honours the
    /// configured loop-blocking factor `s`; classical variants force 1).
    pub fn is_ca(&self) -> bool {
        matches!(self, Method::CaBcd | Method::CaBdcd | Method::CaBcdRow)
    }

    /// The shard layout this method consumes (drives partitioning).
    pub fn layout(&self) -> Layout {
        match self {
            Method::Bcd | Method::CaBcd | Method::Cocoa | Method::Cg => Layout::PrimalCols,
            Method::Bdcd | Method::CaBdcd => Layout::DualCols,
            Method::BcdRow | Method::CaBcdRow => Layout::PrimalRows,
        }
    }

    /// Whether [`Session::run`] requires a compute backend (CG and CoCoA
    /// run on plain matvecs).
    pub fn needs_backend(&self) -> bool {
        !matches!(self, Method::Cg | Method::Cocoa)
    }

    /// Whether this method supports non-smooth regularizers via the
    /// CA-Prox loops (only the matched-layout BCD/BDCD pairs do).
    pub fn supports_prox(&self) -> bool {
        matches!(
            self,
            Method::Bcd | Method::CaBcd | Method::Bdcd | Method::CaBdcd
        )
    }
}

impl std::str::FromStr for Method {
    type Err = Error;

    fn from_str(s: &str) -> Result<Method> {
        Method::parse(s)
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The shard layout a [`Method`] consumes (see [`Method::layout`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// 1D-block-column partition of X (matched primal layout).
    PrimalCols,
    /// 1D-block-column partition of `A = Xᵀ` (matched dual layout).
    DualCols,
    /// 1D-block-row partition of X (the Theorem-4 mismatched layout).
    PrimalRows,
}

/// One rank's view of the problem data, in one of the three layouts.
#[derive(Clone, Copy, Debug)]
pub enum Shard<'a> {
    /// Matched primal layout: `a_loc` is the rank's `d × n_loc` column
    /// block of X, `y_loc` the matching label slice.
    PrimalCols {
        /// Local column block of X.
        a_loc: &'a Matrix,
        /// Local slice of the labels.
        y_loc: &'a [f64],
        /// Total number of data points n.
        n_global: usize,
    },
    /// Matched dual layout: `a_loc` is the rank's `n × d_loc` column
    /// block of `A = Xᵀ` (a feature slice); `y` is replicated.
    DualCols {
        /// Local column block of `A = Xᵀ`.
        a_loc: &'a Matrix,
        /// Full (replicated) label vector.
        y: &'a [f64],
        /// Total feature dimension d.
        d_global: usize,
        /// Global index of this rank's first feature column.
        d_offset: usize,
    },
    /// Mismatched 1D-block-row layout: `x_rows` is the rank's
    /// `d_loc × n` slab of full rows of X; `y_loc` covers the canonical
    /// column range this rank owns.
    PrimalRows {
        /// Local row slab of X.
        x_rows: &'a Matrix,
        /// Label slice for this rank's canonical column range.
        y_loc: &'a [f64],
        /// Total feature dimension d.
        d_global: usize,
        /// Global index of this rank's first row.
        d_offset: usize,
    },
}

/// What is being solved: one rank's data shard plus the optional ridge
/// ground truth. The regularizer ψ(w) rides in [`SolverOpts::reg`], so a
/// `Problem` + [`SolverOpts`] fully determine the objective.
#[derive(Clone, Copy, Debug)]
pub struct Problem<'a> {
    /// This rank's data shard.
    pub shard: Shard<'a>,
    /// Optional `w_opt`/`f_opt` ground truth for error recording
    /// (smooth/ridge runs only; the prox loops record certificates).
    pub reference: Option<&'a Reference>,
}

impl<'a> Problem<'a> {
    /// Matched primal layout problem (see [`Shard::PrimalCols`]).
    pub fn primal(a_loc: &'a Matrix, y_loc: &'a [f64], n_global: usize) -> Problem<'a> {
        Problem {
            shard: Shard::PrimalCols {
                a_loc,
                y_loc,
                n_global,
            },
            reference: None,
        }
    }

    /// Matched dual layout problem (see [`Shard::DualCols`]).
    pub fn dual(
        a_loc: &'a Matrix,
        y: &'a [f64],
        d_global: usize,
        d_offset: usize,
    ) -> Problem<'a> {
        Problem {
            shard: Shard::DualCols {
                a_loc,
                y,
                d_global,
                d_offset,
            },
            reference: None,
        }
    }

    /// Mismatched 1D-block-row layout problem (see [`Shard::PrimalRows`]).
    pub fn primal_rows(
        x_rows: &'a Matrix,
        y_loc: &'a [f64],
        d_global: usize,
        d_offset: usize,
    ) -> Problem<'a> {
        Problem {
            shard: Shard::PrimalRows {
                x_rows,
                y_loc,
                d_global,
                d_offset,
            },
            reference: None,
        }
    }

    /// Attach (or clear) the ridge ground truth for error recording.
    pub fn with_reference(mut self, reference: Option<&'a Reference>) -> Problem<'a> {
        self.reference = reference;
        self
    }

    /// The default method for this shard's layout (the CA variant).
    fn default_method(&self) -> Method {
        match self.shard {
            Shard::PrimalCols { .. } => Method::CaBcd,
            Shard::DualCols { .. } => Method::CaBdcd,
            Shard::PrimalRows { .. } => Method::CaBcdRow,
        }
    }
}

/// The result of a [`Session::run`], one variant per output shape.
#[derive(Clone, Debug)]
pub enum Solution {
    /// Matched-layout primal solvers (BCD / CA-BCD / CA-Prox-BCD).
    Primal(PrimalOutput),
    /// Matched-layout dual solvers (BDCD / CA-BDCD / CA-Prox-BDCD).
    Dual(DualOutput),
    /// Row-layout primal solver (Theorem 4/8).
    RowPrimal(bcd_row::RowPrimalOutput),
    /// The CoCoA baseline.
    Cocoa(CocoaOutput),
    /// The CG baseline.
    Cg(CgOutput),
}

impl Solution {
    /// The run's trajectory + communication accounting, whatever the
    /// method.
    pub fn history(&self) -> &History {
        match self {
            Solution::Primal(o) => &o.history,
            Solution::Dual(o) => &o.history,
            Solution::RowPrimal(o) => &o.history,
            Solution::Cocoa(o) => &o.history,
            Solution::Cg(o) => &o.history,
        }
    }

    /// Consume the solution, keeping only the history.
    pub fn into_history(self) -> History {
        match self {
            Solution::Primal(o) => o.history,
            Solution::Dual(o) => o.history,
            Solution::RowPrimal(o) => o.history,
            Solution::Cocoa(o) => o.history,
            Solution::Cg(o) => o.history,
        }
    }

    /// Unwrap a matched-layout primal output.
    pub fn into_primal(self) -> Result<PrimalOutput> {
        match self {
            Solution::Primal(o) => Ok(o),
            other => Err(Error::InvalidArg(format!(
                "expected a primal solution, got {}",
                other.kind()
            ))),
        }
    }

    /// Unwrap a matched-layout dual output.
    pub fn into_dual(self) -> Result<DualOutput> {
        match self {
            Solution::Dual(o) => Ok(o),
            other => Err(Error::InvalidArg(format!(
                "expected a dual solution, got {}",
                other.kind()
            ))),
        }
    }

    /// Unwrap a row-layout primal output.
    pub fn into_row_primal(self) -> Result<bcd_row::RowPrimalOutput> {
        match self {
            Solution::RowPrimal(o) => Ok(o),
            other => Err(Error::InvalidArg(format!(
                "expected a row-layout solution, got {}",
                other.kind()
            ))),
        }
    }

    /// Unwrap a CoCoA output.
    pub fn into_cocoa(self) -> Result<CocoaOutput> {
        match self {
            Solution::Cocoa(o) => Ok(o),
            other => Err(Error::InvalidArg(format!(
                "expected a CoCoA solution, got {}",
                other.kind()
            ))),
        }
    }

    /// Unwrap a CG output.
    pub fn into_cg(self) -> Result<CgOutput> {
        match self {
            Solution::Cg(o) => Ok(o),
            other => Err(Error::InvalidArg(format!(
                "expected a CG solution, got {}",
                other.kind()
            ))),
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Solution::Primal(_) => "primal",
            Solution::Dual(_) => "dual",
            Solution::RowPrimal(_) => "row-primal",
            Solution::Cocoa(_) => "cocoa",
            Solution::Cg(_) => "cg",
        }
    }
}

/// Builder binding a [`Problem`] to options, method, backend, and
/// communicator; [`Session::run`] is the single entry point every solver
/// loop executes through.
///
/// ```ignore
/// let sol = Session::new(&problem)
///     .opts(opts)
///     .backend(&mut backend)
///     .comm(&mut comm)
///     .run()?;
/// ```
pub struct Session<'a, C: Communicator> {
    problem: &'a Problem<'a>,
    opts: SolverOpts,
    method: Option<Method>,
    local_iters: usize,
    backend: Option<&'a mut dyn ComputeBackend>,
    comm: Option<&'a mut C>,
}

impl<'a, C: Communicator> Session<'a, C> {
    /// Start a session on `problem`. The method defaults to the CA
    /// variant matching the shard layout.
    pub fn new(problem: &'a Problem<'a>) -> Session<'a, C> {
        Session {
            problem,
            opts: SolverOpts::default(),
            method: None,
            local_iters: 100,
            backend: None,
            comm: None,
        }
    }

    /// Set the solver options (block size, s, λ, iters, overlap, reg, …).
    pub fn opts(mut self, opts: SolverOpts) -> Self {
        self.opts = opts;
        self
    }

    /// Override the method (defaults to the shard layout's CA variant).
    pub fn method(mut self, method: Method) -> Self {
        self.method = Some(method);
        self
    }

    /// Local dual updates per round ([`Method::Cocoa`] only; default 100).
    pub fn local_iters(mut self, local_iters: usize) -> Self {
        self.local_iters = local_iters;
        self
    }

    /// Attach the compute backend (required unless the method is CG or
    /// CoCoA — see [`Method::needs_backend`]).
    pub fn backend(mut self, backend: &'a mut dyn ComputeBackend) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Attach this rank's communicator (always required).
    pub fn comm(mut self, comm: &'a mut C) -> Self {
        self.comm = Some(comm);
        self
    }

    /// Resume this session's run from a [`Checkpoint`] instead of
    /// starting at iteration 0. The snapshot is staged on the current
    /// thread; the subsequent [`Session::run`] restores the solver state,
    /// history, and meter, then executes the remaining outer iterations —
    /// bitwise-equal to an uninterrupted run at the same checkpoint
    /// cadence (see the [`checkpoint`] module docs for the schedule
    /// implications). The checkpoint's method tag and rank geometry are
    /// validated inside the engine.
    pub fn resume(self, ckpt: Checkpoint) -> Self {
        checkpoint::stage_resume(ckpt);
        self
    }

    /// Dispatch to the method's [`CaStep`] and run it through the shared
    /// pipeline core. Non-smooth regularizers route the matched-layout
    /// BCD/BDCD methods through the CA-Prox steps (same packed `[G|r]`
    /// payload, same H/s collective count); `reg = l2` takes the exact
    /// Cholesky steps bitwise-unchanged (the L2 escape hatch).
    pub fn run(self) -> Result<Solution> {
        let problem = self.problem;
        let method = self.method.unwrap_or_else(|| problem.default_method());
        let comm = self
            .comm
            .ok_or_else(|| Error::InvalidArg("Session needs .comm(…)".into()))?;
        // The classical variants run the s = 1 algorithm regardless of the
        // configured loop-blocking factor — only the CA methods honour it
        // (CoCoA and CG have no s-step structure to force).
        let mut opts = self.opts;
        if matches!(method, Method::Bcd | Method::Bdcd | Method::BcdRow) {
            opts.s = 1;
        }
        let opts = &opts;
        let prox = !opts.reg.is_exact_l2();
        if prox && !method.supports_prox() {
            return Err(Error::InvalidArg(format!(
                "method {method} supports reg = l2 only; prox regularizers \
                 run through bcd/cabcd/bdcd/cabdcd (matched layouts)"
            )));
        }
        if prox && problem.reference.is_some() && comm.rank() == 0 {
            // Satellite fix: the ridge reference does not apply on the
            // prox path — say so instead of silently dropping it.
            eprintln!(
                "warning: reg = {} routes through the CA-Prox loop; the ridge \
                 `reference` does not apply and is ignored (prox certificates \
                 are recorded instead)",
                opts.reg.name()
            );
        }
        let mut backend = self.backend;
        if method.needs_backend() && backend.is_none() {
            return Err(Error::InvalidArg(format!(
                "Session needs .backend(…) for method {method}"
            )));
        }

        match (method, &problem.shard) {
            (
                Method::Bcd | Method::CaBcd,
                Shard::PrimalCols {
                    a_loc,
                    y_loc,
                    n_global,
                },
            ) => {
                let be = backend.take().ok_or_else(|| {
                    Error::InvalidArg(format!("Session needs .backend(…) for method {method}"))
                })?;
                if prox {
                    crate::prox::bcd::run(a_loc, y_loc, *n_global, opts, comm, be)
                        .map(Solution::Primal)
                } else {
                    bcd::engine_run(a_loc, y_loc, *n_global, opts, problem.reference, comm, be)
                        .map(Solution::Primal)
                }
            }
            (
                Method::Bdcd | Method::CaBdcd,
                Shard::DualCols {
                    a_loc,
                    y,
                    d_global,
                    d_offset,
                },
            ) => {
                let be = backend.take().ok_or_else(|| {
                    Error::InvalidArg(format!("Session needs .backend(…) for method {method}"))
                })?;
                if prox {
                    crate::prox::bdcd::run(a_loc, y, *d_global, *d_offset, opts, comm, be)
                        .map(Solution::Dual)
                } else {
                    bdcd::engine_run(
                        a_loc,
                        y,
                        *d_global,
                        *d_offset,
                        opts,
                        problem.reference,
                        comm,
                        be,
                    )
                    .map(Solution::Dual)
                }
            }
            (
                Method::BcdRow | Method::CaBcdRow,
                Shard::PrimalRows {
                    x_rows,
                    y_loc,
                    d_global,
                    d_offset,
                },
            ) => {
                let be = backend.take().ok_or_else(|| {
                    Error::InvalidArg(format!("Session needs .backend(…) for method {method}"))
                })?;
                bcd_row::engine_run(
                    x_rows,
                    y_loc,
                    *d_global,
                    *d_offset,
                    opts,
                    problem.reference,
                    comm,
                    be,
                )
                .map(Solution::RowPrimal)
            }
            (
                Method::Cocoa,
                Shard::PrimalCols {
                    a_loc,
                    y_loc,
                    n_global,
                },
            ) => {
                if self.local_iters == 0 {
                    return Err(Error::InvalidArg(
                        "CoCoA needs local_iters ≥ 1 (0 would allreduce \
                         all-zero Δw every round)"
                            .into(),
                    ));
                }
                let copts = CocoaOpts {
                    lam: opts.lam,
                    rounds: opts.iters,
                    local_iters: self.local_iters,
                    seed: opts.seed,
                    record_every: opts.record_every,
                    overlap: opts.overlap,
                };
                cocoa::run(a_loc, y_loc, *n_global, &copts, problem.reference, comm)
                    .map(Solution::Cocoa)
            }
            (
                Method::Cg,
                Shard::PrimalCols {
                    a_loc,
                    y_loc,
                    n_global,
                },
            ) => {
                if checkpoint::resume_staged() {
                    // Consume the stale staging so it cannot leak into an
                    // unrelated later run on this thread.
                    let _ = checkpoint::take_staged();
                    return Err(Error::InvalidArg(
                        "method cg does not run through the s-step engine and \
                         cannot resume from a checkpoint"
                            .into(),
                    ));
                }
                let copts = CgOpts {
                    lam: opts.lam,
                    max_iters: opts.iters,
                    tol: opts.tol.unwrap_or(1e-12),
                    record_every: opts.record_every,
                };
                cg::run(a_loc, y_loc, *n_global, &copts, problem.reference, comm)
                    .map(Solution::Cg)
            }
            (method, shard) => Err(Error::InvalidArg(format!(
                "method {method} needs a {:?} shard, got {:?}",
                method.layout(),
                match shard {
                    Shard::PrimalCols { .. } => Layout::PrimalCols,
                    Shard::DualCols { .. } => Layout::DualCols,
                    Shard::PrimalRows { .. } => Layout::PrimalRows,
                }
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::matrix::DenseMatrix;

    fn toy() -> (Matrix, Vec<f64>) {
        let mut st = 77u64;
        let data: Vec<f64> = (0..6 * 40)
            .map(|_| {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                (st as f64 / u64::MAX as f64) - 0.5
            })
            .collect();
        let x = Matrix::Dense(DenseMatrix::from_vec(6, 40, data));
        let mut y = vec![0.0; 40];
        x.matvec_t(&[1.0; 6], &mut y).unwrap();
        (x, y)
    }

    #[test]
    fn method_parsing_round_trips_and_rejects_unknown() {
        for m in [
            Method::Bcd,
            Method::CaBcd,
            Method::Bdcd,
            Method::CaBdcd,
            Method::BcdRow,
            Method::CaBcdRow,
            Method::Cocoa,
            Method::Cg,
        ] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("sgd").is_err());
        assert!("cabcd".parse::<Method>().unwrap().is_ca());
        assert!(!"bcd".parse::<Method>().unwrap().is_ca());
    }

    #[test]
    fn session_defaults_to_layout_ca_method() {
        let (x, y) = toy();
        let problem = Problem::primal(&x, &y, 40);
        let opts = SolverOpts::builder().b(2).s(3).lam(0.05).iters(12).build();
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let sol = Session::new(&problem)
            .opts(opts)
            .backend(&mut be)
            .comm(&mut comm)
            .run()
            .unwrap();
        assert!(matches!(sol, Solution::Primal(_)));
    }

    #[test]
    fn session_rejects_layout_mismatch_and_missing_backend() {
        let (x, y) = toy();
        let problem = Problem::primal(&x, &y, 40);
        let mut comm = SerialComm::new();
        let err = Session::new(&problem)
            .method(Method::CaBdcd)
            .backend(&mut NativeBackend::new())
            .comm(&mut comm)
            .run();
        assert!(err.is_err(), "dual method on a primal shard must fail");
        let err = Session::new(&problem)
            .method(Method::CaBcd)
            .comm(&mut comm)
            .run();
        assert!(err.is_err(), "missing backend must fail");
    }

    #[test]
    fn session_matches_wrapper_entry_point() {
        let (x, y) = toy();
        let opts = SolverOpts::builder().b(2).s(2).lam(0.05).iters(20).build();
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w_wrapper = bcd::run(&x, &y, 40, &opts, None, &mut comm, &mut be)
            .unwrap()
            .w;
        let problem = Problem::primal(&x, &y, 40);
        let w_session = Session::new(&problem)
            .opts(opts)
            .backend(&mut be)
            .comm(&mut comm)
            .run()
            .unwrap()
            .into_primal()
            .unwrap()
            .w;
        assert_eq!(w_wrapper, w_session);
    }
}
