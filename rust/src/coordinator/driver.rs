//! End-to-end experiment driver: config → dataset → shards → SPMD solve →
//! report. This is the launcher's core and what the examples call.
//!
//! Dispatch is on the parsed [`Method`] enum (unknown method strings fail
//! at config load), and every solver runs through the engine's single
//! [`Session`](crate::engine::Session) entry point — the driver only
//! chooses the partitioning for the method's layout.

use std::process::{Child, Command};
use std::time::{Duration, Instant};

use crate::comm::cost::CostMeter;
use crate::comm::process::{self, Rendezvous};
use crate::comm::thread::run_spmd;
use crate::comm::{gather_to_root, Communicator, SerialComm, Topology};
use crate::config::ExperimentConfig;
use crate::engine::{checkpoint, FileSink, Layout, Method, Problem, Session};
use crate::error::{Error, Result};
use crate::gram::{ComputeBackend, NativeBackend};
use crate::matrix::gen::{self, DatasetSpec};
use crate::matrix::io::{read_libsvm, Dataset};
use crate::metrics::{History, Reference};
use crate::runtime::XlaBackend;
use crate::solvers::cg;
use crate::telemetry::{self, Registry, TelemetrySummary};
use crate::trace::{self, TraceSummary, Tracer};

use super::{partition_dual, partition_primal, partition_rows, DualShard, PrimalShard, RowShard};

/// Everything an experiment produces.
#[derive(Clone, Debug)]
pub struct ExperimentReport {
    pub dataset: String,
    pub d: usize,
    pub n: usize,
    pub method: String,
    pub b: usize,
    pub s: usize,
    pub ranks: usize,
    pub lambda: f64,
    pub backend: String,
    /// Whether the non-blocking overlap pipeline was enabled.
    pub overlap: bool,
    /// Regularizer name (`l2` runs the exact solvers; anything else runs
    /// the CA-Prox loops and reports the prox certificates below).
    pub reg: String,
    /// Rank-group transport the solve ran over (`thread` or `process`).
    pub transport: String,
    /// Collective topology (`flat` or `twolevel`).
    pub topology: String,
    /// Ranks per node under `topology = twolevel` (1 under `flat`).
    pub node_size: usize,
    /// Driver-level advisories (e.g. "prox run: ridge reference skipped")
    /// — surfaced on stderr and in the report JSON so nothing is dropped
    /// silently.
    pub notes: Vec<String>,
    pub wall_ms: f64,
    /// Rank-0 trajectory.
    pub history: History,
    /// Critical-path communication over all ranks (messages, words).
    pub critical_msgs: u64,
    pub critical_words: u64,
    pub final_obj_err: f64,
    pub final_sol_err: f64,
    /// Per-rank span-trace summary (`[run] trace` / `--trace` only):
    /// compute/wire/idle breakdown, per-kind histograms, and the
    /// overlap-efficiency accounting. The raw Chrome trace-event JSON is
    /// written to the configured path.
    pub trace: Option<TraceSummary>,
    /// Cluster-health rollup (`[run] telemetry` / `--telemetry` only):
    /// snapshot counts, the steady-state allocation tripwire, straggler
    /// verdicts, and the final [`ClusterSnapshot`](telemetry::ClusterSnapshot).
    /// The full snapshot JSON and the Prometheus exposition are written
    /// to the configured path (and its `.prom` sibling).
    pub telemetry: Option<TelemetrySummary>,
    /// Set when the SPMD solve aborted (poisoned group, rank death,
    /// exhausted retry budget, …). The report then carries everything the
    /// ranks produced up to the failure — per-rank meters, the failing
    /// collective, and the checkpoint to resume from — instead of
    /// discarding the run.
    pub aborted_at: Option<AbortInfo>,
}

/// Where and why an SPMD solve stopped early. `run_experiment` returns a
/// *partial* [`ExperimentReport`] carrying this instead of an `Err`, so a
/// multi-hour run that dies keeps its measurements and names the
/// checkpoint to resume from.
#[derive(Clone, Debug)]
pub struct AbortInfo {
    /// Lowest-numbered failing rank (every poisoned rank fails; this one
    /// is the report's exemplar).
    pub rank: usize,
    /// That rank's error — the poison diagnostic, which names the peer
    /// and the collective's operation tag.
    pub error: String,
    /// Collectives the failing rank had completed (allreduces +
    /// all-to-alls): the ordinal of the operation that failed, and — at
    /// one solver collective per outer iteration — an upper bound on the
    /// outer iteration reached.
    pub collectives_done: u64,
    /// Outer iteration (s-step block index) a resume would restart from:
    /// `next_k` of the failing rank's last on-disk checkpoint. `None`
    /// when checkpointing was off or nothing was snapshotted yet.
    pub resume_at: Option<u64>,
    /// The failing rank's checkpoint file, when checkpointing was on.
    pub checkpoint: Option<String>,
    /// Per-rank meters at failure (index = rank), including the
    /// fault-path counters `retries` and `timeouts`.
    pub meters: Vec<CostMeter>,
}

/// Load the configured dataset (synthetic clone or LIBSVM file) and its λ.
pub fn load_dataset(cfg: &ExperimentConfig) -> Result<(Dataset, f64)> {
    match cfg.dataset.kind.as_str() {
        "synthetic" => {
            let name = cfg.dataset.name.as_ref().ok_or_else(|| {
                Error::Config("synthetic datasets need `dataset.name`".into())
            })?;
            let mut spec: DatasetSpec = gen::spec_by_name(name)?;
            if cfg.dataset.scale > 1 {
                let f = cfg.dataset.scale;
                spec.name = format!("{}-s{}", spec.name, f);
                spec.d = (spec.d / f).max(4);
                spec.n = (spec.n / f).max(16);
            }
            let lam = cfg.effective_lambda(spec.lambda());
            Ok((gen::generate(&spec, cfg.dataset.seed)?, lam))
        }
        "libsvm" => {
            let path = cfg.dataset.path.as_ref().ok_or_else(|| {
                Error::Config("libsvm datasets need `dataset.path`".into())
            })?;
            let ds = read_libsvm(path, None)?;
            let lam = cfg
                .solver
                .lam
                .ok_or_else(|| Error::Config("libsvm datasets need explicit `lam`".into()))?;
            Ok((ds, lam))
        }
        other => Err(Error::Config(format!(
            "unknown dataset kind `{other}` (config validation should have caught this)"
        ))),
    }
}

fn make_backend(cfg: &ExperimentConfig) -> Result<Box<dyn ComputeBackend>> {
    match cfg.run.backend.as_str() {
        "native" => Ok(Box::new(NativeBackend::new())),
        "xla" => Ok(Box::new(XlaBackend::new(&cfg.run.artifact_dir)?)),
        other => Err(Error::Config(format!(
            "unknown backend `{other}` (config validation should have caught this)"
        ))),
    }
}

/// The per-layout shard sets the SPMD closure picks a rank's problem from.
enum ShardSet {
    Primal(Vec<PrimalShard>),
    Dual(Vec<DualShard>),
    Rows(Vec<RowShard>),
}

impl ShardSet {
    fn partition(method: Method, ds: &Dataset, p: usize) -> Result<ShardSet> {
        Ok(match method.layout() {
            Layout::PrimalCols => ShardSet::Primal(partition_primal(ds, p)?),
            Layout::DualCols => ShardSet::Dual(partition_dual(ds, p)?),
            Layout::PrimalRows => ShardSet::Rows(partition_rows(ds, p)?),
        })
    }

    fn problem(&self, rank: usize) -> Problem<'_> {
        match self {
            ShardSet::Primal(v) => {
                let sh = &v[rank];
                Problem::primal(&sh.a_loc, &sh.y_loc, sh.n_global)
            }
            ShardSet::Dual(v) => {
                let sh = &v[rank];
                Problem::dual(&sh.a_loc, &sh.y, sh.d_global, sh.d_offset)
            }
            ShardSet::Rows(v) => {
                let sh = &v[rank];
                Problem::primal_rows(&sh.x_rows, &sh.y_loc, sh.d_global, sh.d_offset)
            }
        }
    }
}

/// Run one configured experiment end to end.
///
/// `[run] transport` picks the rank group's substrate: `thread` (default)
/// solves inside this process over in-memory channels; `process` re-execs
/// the current executable into P OS processes wired over loopback TCP
/// (see [`maybe_run_process_child`] for the worker-side entry point).
/// Both transports run the identical per-rank code ([`run_rank`]) against
/// the [`Communicator`] seam and produce bitwise-identical trajectories,
/// wire meters, and certificates.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<ExperimentReport> {
    cfg.validate()?;
    if cfg.run.transport == "process" {
        run_experiment_process(cfg)
    } else {
        run_experiment_threaded(cfg)
    }
}

/// Everything both transports derive from the config before any rank
/// starts. All of it is a pure function of the config, so process-mode
/// workers recompute it locally and arrive at bitwise-identical inputs.
struct Prepared {
    method: Method,
    ds: Dataset,
    lam: f64,
    opts: crate::solvers::SolverOpts,
    topology: Topology,
    reference: Option<Reference>,
    notes: Vec<String>,
}

fn prepare(cfg: &ExperimentConfig, quiet: bool) -> Result<Prepared> {
    let method = cfg.method()?;
    let (ds, lam) = load_dataset(cfg)?;
    let opts = cfg.solver_opts(lam);
    let topology = cfg.topology()?;
    let mut notes: Vec<String> = Vec::new();
    // Ground truth from serial CG (excluded from all meters). The prox
    // runs have no ridge ground truth — they report the duality-gap /
    // subgradient certificates instead, so the CG solve is skipped and
    // the report says so (nothing is dropped silently).
    let reference = if opts.reg.is_exact_l2() {
        let mut comm = SerialComm::new();
        Some(cg::compute_reference(&ds.x, &ds.y, ds.n(), lam, &mut comm)?)
    } else {
        let note = format!(
            "reg = {}: ridge reference/CG ground truth does not apply; \
             reporting prox certificates instead of reference errors",
            cfg.solver.reg
        );
        if !quiet {
            eprintln!("note: {note}");
        }
        notes.push(note);
        None
    };
    Ok(Prepared {
        method,
        ds,
        lam,
        opts,
        topology,
        reference,
        notes,
    })
}

/// The shared inputs one rank's solve needs, bundled so the thread
/// closure and the process workers call literally the same [`run_rank`].
struct RankPlan<'a> {
    cfg: &'a ExperimentConfig,
    method: Method,
    opts: &'a crate::solvers::SolverOpts,
    shards: &'a ShardSet,
    reference: Option<&'a Reference>,
    topology: Topology,
    ranks: usize,
}

/// One rank's whole solve — both transports run this verbatim, so any
/// divergence between them is a transport bug, not a driver bug.
fn run_rank<C: Communicator>(plan: &RankPlan<'_>, rank: usize, comm: &mut C) -> RankOutcome {
    let cfg = plan.cfg;
    comm.set_topology(plan.topology);
    if cfg.run.trace.is_some() {
        // Per-rank tracer lives in this worker's thread-local slot for
        // the whole solve; reclaimed below even on error so a failed
        // rank cannot leak an active tracer into a reused thread.
        trace::install(Tracer::new(rank, trace::DEFAULT_SPAN_CAPACITY));
    }
    if cfg.run.telemetry.is_some() {
        // Same thread-local discipline as the tracer. Installed on
        // every rank (the aggregation collective must be lockstep);
        // only rank 0 prints the live progress line.
        let mut reg = Registry::new(rank, plan.ranks).with_live(rank == 0);
        if let Some(z) = cfg.run.telemetry_z {
            reg = reg.with_z_threshold(z);
        }
        telemetry::install(reg);
    }
    if let Some(ms) = cfg.run.comm_timeout_ms {
        comm.set_deadline(Some(Duration::from_millis(ms)));
    }
    let run_one = || -> Result<History> {
        if cfg.run.checkpoint_every > 0 {
            let dir = cfg
                .run
                .checkpoint_dir
                .clone()
                .unwrap_or_else(|| cfg.run.artifact_dir.join("checkpoints"));
            checkpoint::install(
                Box::new(FileSink::new(dir)?),
                cfg.run.checkpoint_every,
            );
        }
        let mut be = if plan.method.needs_backend() {
            Some(make_backend(cfg)?)
        } else {
            None
        };
        let problem = plan.shards.problem(rank).with_reference(plan.reference);
        let mut session = Session::new(&problem)
            .opts(plan.opts.clone())
            .method(plan.method)
            .local_iters(cfg.solver.local_iters)
            .comm(comm);
        if let Some(be) = be.as_mut() {
            session = session.backend(be.as_mut());
        }
        Ok(session.run()?.into_history())
    };
    let history = run_one();
    // Reclaim the thread-local sink even on error (reused worker
    // threads must not inherit it), but remember where it wrote so an
    // abort report can name the file to resume from.
    let ckpt = checkpoint::describe_sink(rank);
    checkpoint::take();
    RankOutcome {
        meter: *comm.meter(),
        tracer: trace::take(),
        registry: telemetry::take(),
        checkpoint: ckpt,
        history,
    }
}

fn run_experiment_threaded(cfg: &ExperimentConfig) -> Result<ExperimentReport> {
    let p = cfg.run.ranks;
    let prep = prepare(cfg, false)?;
    let (d, n) = (prep.ds.d(), prep.ds.n());
    let start = Instant::now();
    let shards = ShardSet::partition(prep.method, &prep.ds, p)?;
    let plan = RankPlan {
        cfg,
        method: prep.method,
        opts: &prep.opts,
        shards: &shards,
        reference: prep.reference.as_ref(),
        topology: prep.topology,
        ranks: p,
    };
    let outcomes: Vec<RankOutcome> =
        run_spmd(p, |rank, comm| run_rank(&plan, rank, comm));
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    finish_report(
        ReportCtx {
            cfg,
            dataset: prep.ds.name.clone(),
            d,
            n,
            lambda: prep.lam,
            opts: &prep.opts,
            notes: prep.notes,
            wall_ms,
        },
        outcomes,
    )
}

/// Shared report-assembly context (everything `finish_report` needs
/// besides the per-rank outcomes).
struct ReportCtx<'a> {
    cfg: &'a ExperimentConfig,
    dataset: String,
    d: usize,
    n: usize,
    lambda: f64,
    opts: &'a crate::solvers::SolverOpts,
    notes: Vec<String>,
    wall_ms: f64,
}

/// Turn the per-rank outcomes into the final [`ExperimentReport`]: abort
/// detection, note collection, trace/telemetry artifact writing, and the
/// critical-path rollup — identical for both transports.
fn finish_report(ctx: ReportCtx<'_>, outcomes: Vec<RankOutcome>) -> Result<ExperimentReport> {
    let ReportCtx {
        cfg,
        dataset,
        d,
        n,
        lambda,
        opts,
        mut notes,
        wall_ms,
    } = ctx;
    let meters: Vec<CostMeter> = outcomes.iter().map(|o| o.meter).collect();
    let aborted_at = abort_info(&outcomes, &meters);
    let (history, tracers, registries) = collect(outcomes, &mut notes);
    if let Some(a) = &aborted_at {
        let note = format!(
            "aborted: rank {} failed after {} collectives: {}",
            a.rank, a.collectives_done, a.error
        );
        eprintln!("note: {note}");
        notes.push(note);
        let note = match (&a.checkpoint, a.resume_at) {
            (Some(path), Some(k)) => format!(
                "resume from checkpoint {path} (restarts at s-step block {k})"
            ),
            (Some(path), None) => format!(
                "checkpointing was on ({path}) but no block completed before \
                 the fault; rerun from scratch"
            ),
            _ => "no checkpoint to resume from (set [run] checkpoint_every)".into(),
        };
        eprintln!("note: {note}");
        notes.push(note);
    }

    let trace_summary = if let Some(path) = cfg.run.trace.as_ref() {
        // Observer gate: every rank's span counts must agree exactly with
        // its CostMeter (one CollectiveStart per posted collective, one
        // CollectiveWait span per completion). A mismatch is an
        // instrumentation bug — surface it as a report advisory rather
        // than failing the solve. Skipped on abort: a poisoned rank
        // legitimately dies between a start and its wait.
        if aborted_at.is_none() {
            for (tracer, meter) in tracers.iter().zip(&meters) {
                if let Err(e) = trace::cross_check(tracer, meter) {
                    let note = format!("trace/meter cross-check failed: {e}");
                    eprintln!("note: {note}");
                    notes.push(note);
                }
            }
        }
        std::fs::write(path, trace::chrome_trace_json(&tracers))?;
        Some(TraceSummary::from_tracers(&tracers))
    } else {
        None
    };

    // Like the trace above, telemetry artifacts are written even when the
    // run aborted: the partial snapshots and per-rank fault counters are
    // exactly what a postmortem needs. The Prometheus exposition goes to
    // the JSON path's `.prom` sibling.
    let telemetry_summary = if let Some(path) = cfg.run.telemetry.as_ref() {
        std::fs::write(path, telemetry::snapshots_json(&registries))?;
        std::fs::write(
            path.with_extension("prom"),
            telemetry::prometheus_text(&registries),
        )?;
        Some(TelemetrySummary::from_registries(&registries))
    } else {
        None
    };

    let (critical_msgs, critical_words) = CostMeter::critical_path(&meters);
    Ok(ExperimentReport {
        dataset,
        d,
        n,
        method: cfg.solver.method.clone(),
        b: opts.b,
        s: opts.s,
        ranks: cfg.run.ranks,
        lambda,
        backend: cfg.run.backend.clone(),
        overlap: opts.overlap,
        reg: {
            use crate::prox::Regularizer;
            opts.reg.name().to_string()
        },
        transport: cfg.run.transport.clone(),
        topology: cfg.run.topology.clone(),
        node_size: if cfg.run.topology == "twolevel" {
            cfg.run.node_size
        } else {
            1
        },
        notes,
        wall_ms,
        final_obj_err: history.final_obj_err(),
        final_sol_err: history.final_sol_err(),
        history,
        critical_msgs,
        critical_words,
        trace: trace_summary,
        telemetry: telemetry_summary,
        aborted_at,
    })
}

/// Environment variable carrying the serialized experiment config
/// ([`ExperimentConfig::to_ini`]) to re-exec'd worker ranks.
pub const ENV_CONFIG: &str = "CABCD_PROC_CONFIG";
/// Extra argv words (whitespace-separated) appended when re-exec'ing
/// worker ranks. The integration tests use it to route workers into the
/// test harness's child entry point; wrapper scripts can use it to
/// interpose a profiler or launcher shim.
pub const ENV_SPAWN_ARGS: &str = "CABCD_PROC_SPAWN_ARGS";

/// Worker-rank entry point for the process transport. When the
/// `CABCD_PROC_*` rendezvous environment is present this process was
/// re-exec'd (or externally launched) as a worker rank: parse the config
/// shipped in [`ENV_CONFIG`], run the rank via [`run_process_child`], and
/// return `Ok(true)` — the caller should then exit without doing anything
/// else. Returns `Ok(false)` in a normal (non-worker) process. Any binary
/// that may host `transport = process` experiments must call this first
/// thing in `main`, because the launcher re-execs the current executable.
pub fn maybe_run_process_child() -> Result<bool> {
    let Some((addr, rank, ranks)) = process::child_spec_from_env() else {
        return Ok(false);
    };
    let text = std::env::var(ENV_CONFIG).map_err(|_| {
        Error::Comm(format!(
            "worker rank {rank}: {ENV_CONFIG} is not set (the launcher ships \
             the experiment config through the environment)"
        ))
    })?;
    let cfg = ExperimentConfig::from_str(&text)?;
    run_process_child(&cfg, &addr, rank, ranks)?;
    Ok(true)
}

/// Run one worker rank of a process-transport experiment: dial the
/// rendezvous, solve, then feed the outcome gathers. Deterministic
/// preparation (dataset generation, partitioning, the CG reference) is
/// recomputed locally — every rank derives bitwise-identical inputs from
/// the shared config, so nothing but collective payloads crosses the
/// wire. Externally launched ranks (outside the in-tree launcher) call
/// this too, with the rendezvous address distributed however they like.
pub fn run_process_child(
    cfg: &ExperimentConfig,
    addr: &str,
    rank: usize,
    ranks: usize,
) -> Result<()> {
    cfg.validate()?;
    if ranks != cfg.run.ranks {
        return Err(Error::Comm(format!(
            "worker rank {rank}: launched into a {ranks}-rank group but the \
             config says ranks = {}",
            cfg.run.ranks
        )));
    }
    let prep = prepare(cfg, true)?;
    let shards = ShardSet::partition(prep.method, &prep.ds, ranks)?;
    let mut comm = process::connect(addr, rank, ranks)?;
    let plan = RankPlan {
        cfg,
        method: prep.method,
        opts: &prep.opts,
        shards: &shards,
        reference: prep.reference.as_ref(),
        topology: prep.topology,
        ranks,
    };
    let outcome = run_rank(&plan, rank, &mut comm);
    let solve_err = outcome.history.as_ref().err().map(|e| e.to_string());
    // Feed the outcome gathers even when the solve failed locally — the
    // status blob carries the error, so the parent's report names it.
    // Only a broken group (the gather itself erroring) skips this.
    gather_rank_outcomes(&mut comm, &outcome)?;
    match solve_err {
        None => Ok(()),
        Some(e) => Err(Error::Comm(format!("rank {rank} solve failed: {e}"))),
    }
}

fn run_experiment_process(cfg: &ExperimentConfig) -> Result<ExperimentReport> {
    let p = cfg.run.ranks;
    let prep = prepare(cfg, false)?;
    let (d, n) = (prep.ds.d(), prep.ds.n());
    let start = Instant::now();
    let shards = ShardSet::partition(prep.method, &prep.ds, p)?;

    let rdv = Rendezvous::bind()?;
    let mut children = spawn_worker_ranks(cfg, rdv.addr(), p)?;
    let mut comm = match rdv.accept(p) {
        Ok(c) => c,
        Err(e) => {
            reap_children(&mut children, true);
            return Err(e);
        }
    };
    let plan = RankPlan {
        cfg,
        method: prep.method,
        opts: &prep.opts,
        shards: &shards,
        reference: prep.reference.as_ref(),
        topology: prep.topology,
        ranks: p,
    };
    let own = run_rank(&plan, 0, &mut comm);
    let gathered = gather_rank_outcomes(&mut comm, &own);
    let gather_ok = matches!(gathered, Ok(Some(_)));
    // Closing the sockets first lets a worker blocked on a receive fail
    // fast instead of waiting out its deadline before it can exit.
    drop(comm);
    // When the gather completed, every worker finished its part of the
    // epilogue and is exiting — wait for clean statuses. When it did not,
    // waiting risks joining a wedged process: kill instead.
    let exit_notes = reap_children(&mut children, !gather_ok);
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    let mut notes = prep.notes;
    notes.extend(exit_notes);
    let outcomes = match gathered {
        Ok(Some(remote)) => {
            let mut v = Vec::with_capacity(p);
            v.push(own);
            v.extend(remote);
            v
        }
        // `gather_to_root` always yields the root payload on rank 0, but
        // degrade gracefully rather than panic if that ever breaks.
        Ok(None) => parent_view_outcomes(own, p, "outcome gather returned no root payload"),
        Err(e) => parent_view_outcomes(own, p, &e.to_string()),
    };
    finish_report(
        ReportCtx {
            cfg,
            dataset: prep.ds.name.clone(),
            d,
            n,
            lambda: prep.lam,
            opts: &prep.opts,
            notes,
            wall_ms,
        },
        outcomes,
    )
}

/// Re-exec the current executable into worker ranks 1..P, handing each
/// its rendezvous coordinates and the serialized config through the
/// environment. Workers inherit stdout/stderr so their diagnostics land
/// in the launcher's streams.
fn spawn_worker_ranks(cfg: &ExperimentConfig, addr: &str, ranks: usize) -> Result<Vec<Child>> {
    let exe = std::env::current_exe()
        .map_err(|e| Error::Comm(format!("launcher: current_exe unavailable: {e}")))?;
    let extra: Vec<String> = std::env::var(ENV_SPAWN_ARGS)
        .map(|v| v.split_whitespace().map(String::from).collect())
        .unwrap_or_default();
    let ini = cfg.to_ini();
    let mut children: Vec<Child> = Vec::with_capacity(ranks.saturating_sub(1));
    for rank in 1..ranks {
        let spawned = Command::new(&exe)
            .args(&extra)
            .env(process::ENV_ADDR, addr)
            .env(process::ENV_RANK, rank.to_string())
            .env(process::ENV_RANKS, ranks.to_string())
            .env(ENV_CONFIG, &ini)
            .spawn();
        match spawned {
            Ok(c) => children.push(c),
            Err(e) => {
                reap_children(&mut children, true);
                return Err(Error::Comm(format!(
                    "launcher: spawning worker rank {rank} failed: {e}"
                )));
            }
        }
    }
    Ok(children)
}

/// Wait for (or, with `kill`, terminate) the worker processes. Returns a
/// note per worker that did not exit cleanly.
fn reap_children(children: &mut Vec<Child>, kill: bool) -> Vec<String> {
    let mut notes = Vec::new();
    for (i, child) in children.iter_mut().enumerate() {
        let rank = i + 1;
        if kill {
            let _ = child.kill();
        }
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => notes.push(format!("worker rank {rank} exited with {status}")),
            Err(e) => notes.push(format!("worker rank {rank} could not be reaped: {e}")),
        }
    }
    children.clear();
    notes
}

/// Fallback outcome set when the epilogue gather itself failed (a worker
/// died, or the group poisoned before the gathers ran): the report keeps
/// rank 0's own view and records why the other ranks' outcomes are
/// missing. Their meters read zero — the critical-path rollup is then a
/// lower bound, which the abort note makes inspectable.
fn parent_view_outcomes(own: RankOutcome, ranks: usize, why: &str) -> Vec<RankOutcome> {
    let mut v = Vec::with_capacity(ranks);
    v.push(own);
    for rank in 1..ranks {
        v.push(RankOutcome {
            history: Err(Error::Comm(format!(
                "rank {rank} outcome not collected: {why}"
            ))),
            tracer: None,
            registry: None,
            meter: CostMeter::default(),
            checkpoint: None,
        });
    }
    v
}

/// Post-solve epilogue every process-transport rank runs in lockstep:
/// three [`gather_to_root`] collectives move each rank's status + wire
/// meter, span trace, and telemetry registry to rank 0. Returns the
/// decoded outcomes for ranks 1..P on rank 0, `None` elsewhere. Runs
/// after [`run_rank`] reclaimed the rank's tracer/registry, so the
/// epilogue's own traffic never contaminates the measurements.
fn gather_rank_outcomes<C: Communicator>(
    comm: &mut C,
    own: &RankOutcome,
) -> Result<Option<Vec<RankOutcome>>> {
    let status = encode_status(own);
    let trace_words = own.tracer.as_ref().map(Tracer::to_words).unwrap_or_default();
    let telem_words = own
        .registry
        .as_ref()
        .map(Registry::export_words)
        .unwrap_or_default();
    let statuses = gather_to_root(comm, &status)?;
    let traces = gather_to_root(comm, &trace_words)?;
    let telems = gather_to_root(comm, &telem_words)?;
    let (Some(statuses), Some(traces), Some(telems)) = (statuses, traces, telems) else {
        return Ok(None);
    };
    let mut remote = Vec::with_capacity(statuses.len().saturating_sub(1));
    for rank in 1..statuses.len() {
        let (ok, meter, err, checkpoint) =
            decode_status(&statuses[rank]).ok_or_else(|| {
                Error::Comm(format!("malformed status payload from rank {rank}"))
            })?;
        let tracer = if traces[rank].is_empty() {
            None
        } else {
            Some(Tracer::from_words(&traces[rank]).ok_or_else(|| {
                Error::Comm(format!("malformed trace payload from rank {rank}"))
            })?)
        };
        let registry = if telems[rank].is_empty() {
            None
        } else {
            Some(Registry::from_export_words(&telems[rank]).ok_or_else(|| {
                Error::Comm(format!("malformed telemetry payload from rank {rank}"))
            })?)
        };
        remote.push(RankOutcome {
            // Worker histories stay worker-local: the report's trajectory
            // is rank 0's (bitwise-identical across ranks by SPMD), so
            // only success/failure and the failure message travel.
            history: if ok {
                Ok(History::default())
            } else {
                Err(Error::Comm(err))
            },
            tracer,
            registry,
            meter,
            checkpoint,
        });
    }
    Ok(Some(remote))
}

/// Encode one rank's post-solve status for the epilogue gather: ok flag,
/// the 10 [`CostMeter`] fields (bit patterns), the failure message, and
/// the checkpoint path. Strings travel one byte per word — they are a few
/// dozen bytes and cross the wire exactly once.
fn encode_status(own: &RankOutcome) -> Vec<f64> {
    let mut out = Vec::new();
    out.push(if own.history.is_ok() { 1.0 } else { 0.0 });
    let m = &own.meter;
    for v in [
        m.msgs,
        m.words,
        m.recv_msgs,
        m.recv_words,
        m.allreduces,
        m.all_to_alls,
        m.collective_waits,
        m.buf_allocs,
        m.retries,
        m.timeouts,
    ] {
        out.push(f64::from_bits(v));
    }
    let err = match &own.history {
        Err(e) => e.to_string(),
        Ok(_) => String::new(),
    };
    push_str_words(&mut out, &err);
    match &own.checkpoint {
        Some(path) => {
            out.push(1.0);
            push_str_words(&mut out, path);
        }
        None => out.push(0.0),
    }
    out
}

fn decode_status(words: &[f64]) -> Option<(bool, CostMeter, String, Option<String>)> {
    let mut pos = 0usize;
    let ok = *words.first()? == 1.0;
    pos += 1;
    let mut fields = [0u64; 10];
    for f in fields.iter_mut() {
        *f = words.get(pos)?.to_bits();
        pos += 1;
    }
    let meter = CostMeter {
        msgs: fields[0],
        words: fields[1],
        recv_msgs: fields[2],
        recv_words: fields[3],
        allreduces: fields[4],
        all_to_alls: fields[5],
        collective_waits: fields[6],
        buf_allocs: fields[7],
        retries: fields[8],
        timeouts: fields[9],
    };
    let err = read_str_words(words, &mut pos)?;
    let has_ckpt = *words.get(pos)?;
    pos += 1;
    let checkpoint = if has_ckpt == 1.0 {
        Some(read_str_words(words, &mut pos)?)
    } else {
        None
    };
    if pos != words.len() {
        return None;
    }
    Some((ok, meter, err, checkpoint))
}

fn push_str_words(out: &mut Vec<f64>, s: &str) {
    out.push(s.len() as f64);
    out.extend(s.bytes().map(f64::from));
}

fn read_str_words(words: &[f64], pos: &mut usize) -> Option<String> {
    let len = *words.get(*pos)?;
    *pos += 1;
    if !len.is_finite() || len < 0.0 || len > 1e6 {
        return None;
    }
    let len = len as usize;
    let mut bytes = Vec::with_capacity(len);
    for _ in 0..len {
        let b = *words.get(*pos)?;
        *pos += 1;
        if !(0.0..=255.0).contains(&b) || b.fract() != 0.0 {
            return None;
        }
        bytes.push(b as u8);
    }
    String::from_utf8(bytes).ok()
}

impl ExperimentReport {
    /// JSON for downstream tooling (plotting, EXPERIMENTS.md tables).
    pub fn to_json(&self) -> String {
        use crate::util::json::{array, num, object, string};
        let records = array(self.history.records.iter().map(|r| {
            object(&[
                ("iter", num(r.iter as f64)),
                ("obj_err", num(r.obj_err)),
                ("sol_err", num(r.sol_err)),
            ])
        }));
        let conds = array(self.history.gram_conds.iter().map(|&c| num(c)));
        let prox = array(self.history.prox.iter().map(|r| {
            object(&[
                ("iter", num(r.iter as f64)),
                ("pen_obj", num(r.pen_obj)),
                ("gap", num(r.gap)),
                ("subgrad", num(r.subgrad)),
                ("nnz", num(r.nnz as f64)),
            ])
        }));
        let notes = array(self.notes.iter().map(|s| string(s)));
        object(&[
            ("dataset", string(&self.dataset)),
            ("d", num(self.d as f64)),
            ("n", num(self.n as f64)),
            ("method", string(&self.method)),
            ("b", num(self.b as f64)),
            ("s", num(self.s as f64)),
            ("ranks", num(self.ranks as f64)),
            ("lambda", num(self.lambda)),
            ("backend", string(&self.backend)),
            ("transport", string(&self.transport)),
            ("topology", string(&self.topology)),
            ("node_size", num(self.node_size as f64)),
            ("overlap", num(if self.overlap { 1.0 } else { 0.0 })),
            ("reg", string(&self.reg)),
            ("notes", notes),
            ("wall_ms", num(self.wall_ms)),
            ("iters", num(self.history.iters as f64)),
            ("allreduces", num(self.history.meter.allreduces as f64)),
            ("pool_allocs", num(self.history.pool_allocs() as f64)),
            ("critical_msgs", num(self.critical_msgs as f64)),
            ("critical_words", num(self.critical_words as f64)),
            ("final_obj_err", num(self.final_obj_err)),
            ("final_sol_err", num(self.final_sol_err)),
            ("final_pen_obj", num(self.history.final_pen_obj())),
            ("final_gap", num(self.history.final_gap())),
            ("final_subgrad", num(self.history.final_subgrad())),
            (
                "final_nnz",
                num(self
                    .history
                    .final_nnz()
                    .map(|v| v as f64)
                    .unwrap_or(f64::NAN)),
            ),
            (
                "trace",
                self.trace
                    .as_ref()
                    .map(trace::summary_json)
                    .unwrap_or_else(|| "null".into()),
            ),
            (
                "telemetry",
                self.telemetry
                    .as_ref()
                    .map(telemetry::summary_json)
                    .unwrap_or_else(|| "null".into()),
            ),
            (
                "aborted_at",
                self.aborted_at
                    .as_ref()
                    .map(abort_json)
                    .unwrap_or_else(|| "null".into()),
            ),
            ("records", records),
            ("prox_records", prox),
            ("gram_conds", conds),
        ])
    }
}

/// JSON object for [`AbortInfo`] (the report's `"aborted_at"` field).
fn abort_json(a: &AbortInfo) -> String {
    use crate::util::json::{array, num, object, string};
    let meters = array(a.meters.iter().map(|m| {
        object(&[
            ("msgs", num(m.msgs as f64)),
            ("words", num(m.words as f64)),
            ("recv_msgs", num(m.recv_msgs as f64)),
            ("recv_words", num(m.recv_words as f64)),
            ("allreduces", num(m.allreduces as f64)),
            ("all_to_alls", num(m.all_to_alls as f64)),
            ("collective_waits", num(m.collective_waits as f64)),
            ("buf_allocs", num(m.buf_allocs as f64)),
            ("retries", num(m.retries as f64)),
            ("timeouts", num(m.timeouts as f64)),
        ])
    }));
    object(&[
        ("rank", num(a.rank as f64)),
        ("error", string(&a.error)),
        ("collectives_done", num(a.collectives_done as f64)),
        (
            "resume_at",
            a.resume_at
                .map(|k| num(k as f64))
                .unwrap_or_else(|| "null".into()),
        ),
        (
            "checkpoint",
            a.checkpoint
                .as_deref()
                .map(string)
                .unwrap_or_else(|| "null".into()),
        ),
        ("meters", meters),
    ])
}

/// What one rank's SPMD closure hands back: its solve result, plus the
/// observability state that must survive a failed solve (the meter and
/// tracer live in the communicator / thread-local slot, both gone once
/// the worker thread exits).
struct RankOutcome {
    history: Result<History>,
    tracer: Option<Tracer>,
    registry: Option<Registry>,
    meter: CostMeter,
    /// `CheckpointSink::describe` of the installed sink (the per-rank
    /// checkpoint file path), when checkpointing was on.
    checkpoint: Option<String>,
}

/// Build the [`AbortInfo`] for a failed solve — `None` when every rank
/// succeeded. The exemplar is the lowest-numbered failing rank; its last
/// on-disk checkpoint (if any) names the s-step block a resume restarts
/// from.
fn abort_info(outcomes: &[RankOutcome], meters: &[CostMeter]) -> Option<AbortInfo> {
    let (rank, failed) = outcomes
        .iter()
        .enumerate()
        .find(|(_, o)| o.history.is_err())?;
    let error = match &failed.history {
        Err(e) => e.to_string(),
        Ok(_) => unreachable!("find() matched is_err"),
    };
    let checkpoint = failed.checkpoint.clone();
    let resume_at = checkpoint
        .as_deref()
        .and_then(|path| checkpoint::load_checkpoint_file(std::path::Path::new(path)).ok())
        .map(|c| c.next_k);
    Some(AbortInfo {
        rank,
        error,
        collectives_done: meters[rank].allreduces + meters[rank].all_to_alls,
        resume_at,
        checkpoint,
        meters: meters.to_vec(),
    })
}

/// Split the outcomes: the report's history is rank 0's (or the first
/// surviving rank's on abort — an empty default if none survived, with a
/// note saying so), all tracers (when tracing) feed the trace summary,
/// all registries (when telemetering) feed the telemetry exports.
fn collect(
    outcomes: Vec<RankOutcome>,
    notes: &mut Vec<String>,
) -> (History, Vec<Tracer>, Vec<Registry>) {
    let mut histories: Vec<Option<History>> = Vec::with_capacity(outcomes.len());
    let mut tracers = Vec::new();
    let mut registries = Vec::new();
    for o in outcomes {
        histories.push(o.history.ok());
        tracers.extend(o.tracer);
        registries.extend(o.registry);
    }
    let history = match histories.iter_mut().find_map(|h| h.take()) {
        Some(h) => h,
        None => {
            let note = "no rank completed: the report's trajectory fields are empty".to_string();
            eprintln!("note: {note}");
            notes.push(note);
            History::default()
        }
    };
    (history, tracers, registries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, RunConfig, SolverConfig};

    fn cfg(method: &str, ranks: usize) -> ExperimentConfig {
        ExperimentConfig {
            dataset: DatasetConfig {
                kind: "synthetic".into(),
                name: Some("abalone".into()),
                path: None,
                scale: 8,
                seed: 1,
            },
            solver: SolverConfig {
                method: method.into(),
                b: 2,
                s: 4,
                lam: None,
                iters: 200,
                seed: 3,
                record_every: 50,
                track_gram_cond: false,
                tol: None,
                overlap: false,
                reg: "l2".into(),
                l1_ratio: 0.5,
                local_iters: 100,
            },
            run: RunConfig {
                ranks,
                backend: "native".into(),
                transport: "thread".into(),
                topology: "flat".into(),
                node_size: 1,
                artifact_dir: "artifacts".into(),
                trace: None,
                telemetry: None,
                telemetry_z: None,
                comm_timeout_ms: None,
                checkpoint_every: 0,
                checkpoint_dir: None,
            },
        }
    }

    #[test]
    fn cabcd_experiment_end_to_end() {
        let report = run_experiment(&cfg("cabcd", 2)).unwrap();
        assert_eq!(report.method, "cabcd");
        assert_eq!(report.ranks, 2);
        assert!(report.final_obj_err.is_finite());
        assert!(!report.history.records.is_empty());
        assert!(report.critical_msgs > 0, "P=2 must communicate");
        assert!(report.notes.is_empty(), "l2 run should carry no advisories");
    }

    #[test]
    fn rank_count_does_not_change_numerics() {
        let r1 = run_experiment(&cfg("cabcd", 1)).unwrap();
        let r3 = run_experiment(&cfg("cabcd", 3)).unwrap();
        assert!(
            (r1.final_sol_err - r3.final_sol_err).abs() < 1e-9,
            "P=1 {} vs P=3 {}",
            r1.final_sol_err,
            r3.final_sol_err
        );
    }

    #[test]
    fn overlap_pipeline_reproduces_blocking_results() {
        // Same experiment, blocking vs non-blocking comm: identical final
        // errors (the pipeline is bitwise-equivalent) and identical
        // allreduce counts (still one collective per outer iteration).
        let blocking = run_experiment(&cfg("cabcd", 3)).unwrap();
        let mut c = cfg("cabcd", 3);
        c.solver.overlap = true;
        let overlapped = run_experiment(&c).unwrap();
        assert!(overlapped.overlap);
        assert_eq!(
            blocking.final_sol_err, overlapped.final_sol_err,
            "overlap changed the trajectory"
        );
        assert_eq!(
            blocking.history.meter.allreduces,
            overlapped.history.meter.allreduces
        );
    }

    #[test]
    fn dual_experiment_runs() {
        let report = run_experiment(&cfg("cabdcd", 2)).unwrap();
        assert!(report.final_obj_err.is_finite());
    }

    #[test]
    fn row_layout_experiment_matches_matched_layout() {
        // The new driver-level bcdrow method: same trajectory as the
        // matched-column layout under the same seed (Theorem 4/8), one
        // all-to-all per outer iteration on the wire.
        let col = run_experiment(&cfg("cabcd", 2)).unwrap();
        let row = run_experiment(&cfg("cabcdrow", 2)).unwrap();
        assert!(
            (col.final_sol_err - row.final_sol_err).abs() < 1e-9,
            "col {} vs row {}",
            col.final_sol_err,
            row.final_sol_err
        );
        assert_eq!(row.history.meter.all_to_alls as usize, 200 / 4);
    }

    #[test]
    fn cocoa_experiment_runs_through_session() {
        let mut c = cfg("cocoa", 2);
        c.solver.iters = 30; // rounds
        c.solver.local_iters = 50;
        let report = run_experiment(&c).unwrap();
        assert_eq!(report.method, "cocoa");
        assert!(report.final_obj_err.is_finite());
        // One allreduce per round.
        assert_eq!(report.history.meter.allreduces, 30);
    }

    #[test]
    fn lasso_experiment_reports_prox_certificates() {
        let mut c = cfg("cabcd", 2);
        c.solver.reg = "l1".into();
        c.solver.iters = 400;
        let report = run_experiment(&c).unwrap();
        assert_eq!(report.reg, "l1");
        assert!(!report.history.prox.is_empty(), "no prox records");
        assert!(report.history.final_pen_obj().is_finite());
        assert!(report.history.final_gap().is_finite());
        assert!(report.history.final_nnz().is_some());
        // The prox path skips the ridge reference entirely — and says so.
        assert!(report.history.records.is_empty());
        assert!(
            report.notes.iter().any(|n| n.contains("prox certificates")),
            "missing the reference-skipped advisory: {:?}",
            report.notes
        );
        let json = report.to_json();
        assert!(json.contains("\"prox_records\""));
        assert!(json.contains("\"reg\":\"l1\""));
        assert!(json.contains("\"notes\":["));
    }

    #[test]
    fn reg_l2_reports_match_default_path() {
        // `reg = l2` must be indistinguishable from the pre-prox driver:
        // the exact path runs (reference errors recorded, no prox
        // certificates) with identical trajectories, meters, and
        // critical-path counts.
        let base = run_experiment(&cfg("cabcd", 2)).unwrap();
        let mut c = cfg("cabcd", 2);
        c.solver.reg = "l2".into();
        let explicit = run_experiment(&c).unwrap();
        assert!(explicit.history.prox.is_empty(), "l2 routed into the prox loop");
        assert!(!explicit.history.records.is_empty(), "l2 lost the ridge reference path");
        assert_eq!(base.final_sol_err, explicit.final_sol_err);
        assert_eq!(base.history.meter, explicit.history.meter);
        assert_eq!(base.critical_words, explicit.critical_words);
    }

    #[test]
    fn traced_run_is_observer_neutral_and_writes_chrome_json() {
        let mut c = cfg("cabcd", 2);
        c.solver.overlap = true;
        let plain = run_experiment(&c).unwrap();
        let path = std::env::temp_dir().join("cabcd_driver_trace_test.json");
        c.run.trace = Some(path.clone());
        let traced = run_experiment(&c).unwrap();

        // Observer-neutral: identical trajectory and meters with the
        // tracer installed.
        assert_eq!(plain.final_sol_err, traced.final_sol_err);
        assert_eq!(plain.history.meter, traced.history.meter);

        let sum = traced.trace.as_ref().expect("traced run lost its summary");
        assert_eq!(sum.ranks, 2);
        assert!(sum.spans > 0, "no spans recorded");
        assert_eq!(sum.dropped, 0);
        assert!(
            !traced.notes.iter().any(|n| n.contains("cross-check")),
            "span/meter cross-check failed: {:?}",
            traced.notes
        );
        assert!(traced.to_json().contains("\"overlap_efficiency\""));

        let chrome = std::fs::read_to_string(&path).unwrap();
        assert!(chrome.starts_with("{\"traceEvents\":["));
        std::fs::remove_file(&path).ok();
    }

    /// Meter equality modulo `buf_allocs`: the aggregation collective
    /// warms the rank-local buffer pool with its own payload size, so
    /// pool-miss counts may differ while every wire-visible field must
    /// not.
    fn assert_wire_meters_eq(a: &CostMeter, b: &CostMeter) {
        let (mut a, mut b) = (*a, *b);
        a.buf_allocs = 0;
        b.buf_allocs = 0;
        assert_eq!(a, b);
    }

    #[test]
    fn telemetry_run_is_observer_neutral_and_exports() {
        let mut c = cfg("cabcd", 2);
        c.solver.overlap = true;
        let plain = run_experiment(&c).unwrap();
        let path = std::env::temp_dir().join(format!(
            "cabcd_driver_telemetry_{}.json",
            std::process::id()
        ));
        c.run.telemetry = Some(path.clone());
        let telemetered = run_experiment(&c).unwrap();

        // Observer-neutral: identical trajectory and wire meters with the
        // registries installed.
        assert_eq!(plain.final_sol_err, telemetered.final_sol_err);
        assert_wire_meters_eq(&plain.history.meter, &telemetered.history.meter);

        let sum = telemetered
            .telemetry
            .as_ref()
            .expect("telemetered run lost its summary");
        assert_eq!(sum.ranks, 2);
        assert_eq!(sum.snapshot_words, 2 * telemetry::REGISTRY_WORDS);
        // record_every = 50, s = 4 → cadence 48 inner iterations: record
        // boundaries at h = 48, 96, 144, 192, plus the forced final
        // boundary at h = 200 — one cluster snapshot each (none at the
        // h = 0 initial record).
        assert_eq!(sum.snapshots, 5);
        assert_eq!(sum.dropped_snapshots, 0);
        assert_eq!(sum.telemetry_allocs, 0, "steady state must not allocate");
        let last = sum.last.as_ref().expect("no final snapshot");
        assert_eq!(last.h, 200);
        assert_eq!(last.ranks.len(), 2);
        assert!(telemetered.to_json().contains("\"telemetry\":{"));

        let json = std::fs::read_to_string(&path).unwrap();
        assert!(json.starts_with("{\"ranks\":2,"), "{json}");
        let prom = std::fs::read_to_string(path.with_extension("prom")).unwrap();
        assert!(prom.contains("# TYPE cabcd_collectives_total counter"));
        assert!(prom.contains("cabcd_gram_ns_count{rank=\"1\"}"));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(path.with_extension("prom")).ok();
    }

    #[test]
    fn aborted_run_still_exports_trace_and_telemetry() {
        // Same abort-forcing trick as the partial-report test: the
        // checkpoint sink cannot be created under a regular file. The
        // observability artifacts must still land on disk — an aborted
        // multi-hour run with no trace or telemetry is undebuggable.
        let blocker = std::env::temp_dir().join(format!(
            "cabcd_driver_abort_export_{}",
            std::process::id()
        ));
        std::fs::write(&blocker, b"not a directory").unwrap();
        let trace_path = std::env::temp_dir().join(format!(
            "cabcd_driver_abort_trace_{}.json",
            std::process::id()
        ));
        let telem_path = std::env::temp_dir().join(format!(
            "cabcd_driver_abort_telemetry_{}.json",
            std::process::id()
        ));
        let mut c = cfg("cabcd", 2);
        c.run.checkpoint_every = 5;
        c.run.checkpoint_dir = Some(blocker.join("sub"));
        c.run.trace = Some(trace_path.clone());
        c.run.telemetry = Some(telem_path.clone());
        let report = run_experiment(&c).expect("abort must yield a partial report");
        assert!(report.aborted_at.is_some());
        let chrome = std::fs::read_to_string(&trace_path).unwrap();
        assert!(chrome.starts_with("{\"traceEvents\":["), "partial trace missing");
        let json = std::fs::read_to_string(&telem_path).unwrap();
        assert!(json.starts_with("{\"ranks\":2,"), "partial telemetry missing");
        assert!(
            std::fs::read_to_string(telem_path.with_extension("prom"))
                .unwrap()
                .contains("# TYPE cabcd_timeouts_total counter"),
            "partial exposition missing"
        );
        let sum = report.telemetry.as_ref().expect("summary must survive abort");
        assert_eq!(sum.ranks, 2);
        assert_eq!(sum.snapshots, 0, "ranks died before the first record");
        std::fs::remove_file(&blocker).ok();
        std::fs::remove_file(&trace_path).ok();
        std::fs::remove_file(&telem_path).ok();
        std::fs::remove_file(telem_path.with_extension("prom")).ok();
    }

    #[test]
    fn deadline_is_neutral_on_a_healthy_run() {
        // A generous receive deadline must not perturb the trajectory or
        // the wire meters — the timeout path only costs when it fires.
        let plain = run_experiment(&cfg("cabcd", 2)).unwrap();
        let mut c = cfg("cabcd", 2);
        c.run.comm_timeout_ms = Some(60_000);
        let bounded = run_experiment(&c).unwrap();
        assert_eq!(plain.final_sol_err, bounded.final_sol_err);
        assert_eq!(plain.history.meter, bounded.history.meter);
        assert_eq!(bounded.history.meter.timeouts, 0);
        assert!(bounded.aborted_at.is_none());
    }

    #[test]
    fn checkpointed_run_writes_resumable_files() {
        let dir = std::env::temp_dir().join(format!(
            "cabcd_driver_ckpt_{}",
            std::process::id()
        ));
        let plain = run_experiment(&cfg("cabcd", 2)).unwrap();
        let mut c = cfg("cabcd", 2);
        c.run.checkpoint_every = 10;
        c.run.checkpoint_dir = Some(dir.clone());
        let ckpt_run = run_experiment(&c).unwrap();
        // Checkpointing is trajectory-neutral under the blocking schedule.
        assert_eq!(plain.final_sol_err, ckpt_run.final_sol_err);
        // Every rank left a loadable, correctly-typed snapshot behind.
        let sink = FileSink::new(&dir).unwrap();
        for rank in 0..2 {
            let ckpt = sink.load(rank).unwrap().expect("missing checkpoint");
            assert_eq!(ckpt.kind, "bcd");
            assert_eq!(ckpt.rank, rank as u32);
            assert_eq!(ckpt.ranks, 2);
            assert!(ckpt.next_k > 0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_rank_yields_partial_report_with_abort_info() {
        // Force a per-rank failure *inside* the SPMD closure without a
        // fault injector: the checkpoint sink cannot be created under a
        // regular file, so every rank errors before its first collective.
        let blocker = std::env::temp_dir().join(format!(
            "cabcd_driver_abort_{}",
            std::process::id()
        ));
        std::fs::write(&blocker, b"not a directory").unwrap();
        let mut c = cfg("cabcd", 2);
        c.run.checkpoint_every = 5;
        c.run.checkpoint_dir = Some(blocker.join("sub"));
        let report = run_experiment(&c).expect("abort must yield a partial report");
        let a = report.aborted_at.as_ref().expect("missing abort info");
        assert_eq!(a.rank, 0, "exemplar must be the lowest failing rank");
        assert_eq!(a.meters.len(), 2);
        assert_eq!(a.resume_at, None);
        assert!(
            report.notes.iter().any(|n| n.starts_with("aborted:")),
            "abort note missing: {:?}",
            report.notes
        );
        let json = report.to_json();
        assert!(json.contains("\"aborted_at\":{"), "{json}");
        assert!(json.contains("\"collectives_done\""), "{json}");
        assert!(json.contains("\"retries\""), "{json}");
        std::fs::remove_file(&blocker).ok();
    }

    #[test]
    fn report_json_names_transport_and_topology() {
        let report = run_experiment(&cfg("cabcd", 2)).unwrap();
        assert_eq!(report.transport, "thread");
        assert_eq!(report.topology, "flat");
        let json = report.to_json();
        assert!(json.contains("\"transport\":\"thread\""), "{json}");
        assert!(json.contains("\"topology\":\"flat\""), "{json}");
        assert!(json.contains("\"node_size\":1"), "{json}");
    }

    #[test]
    fn twolevel_topology_is_trajectory_neutral_over_threads() {
        // Hierarchical allreduce reroutes the wire protocol and may
        // re-associate the sum (a single 4-rank node accumulates
        // ((r0+r1)+r2)+r3 where recursive doubling computes
        // (r0+r1)+(r2+r3)), so the trajectory agrees to rounding — not
        // bitwise — while rank 0, now the node leader, sends strictly
        // more messages (3 star hops vs 2 recursive-doubling hops per
        // allreduce).
        let flat = run_experiment(&cfg("cabcd", 4)).unwrap();
        let mut c = cfg("cabcd", 4);
        c.run.topology = "twolevel".into();
        c.run.node_size = 4;
        let hier = run_experiment(&c).unwrap();
        assert_eq!(hier.topology, "twolevel");
        assert_eq!(hier.node_size, 4);
        assert!(
            (flat.final_sol_err - hier.final_sol_err).abs()
                <= 1e-9 + 1e-6 * flat.final_sol_err.abs(),
            "two-level topology perturbed the trajectory beyond rounding: \
             flat {} vs twolevel {}",
            flat.final_sol_err,
            hier.final_sol_err
        );
        assert_eq!(flat.history.meter.allreduces, hier.history.meter.allreduces);
        assert!(
            hier.history.meter.msgs > flat.history.meter.msgs,
            "leader fan-out must cost more messages than recursive doubling \
             (hier {} vs flat {})",
            hier.history.meter.msgs,
            flat.history.meter.msgs
        );
        assert!(hier.to_json().contains("\"topology\":\"twolevel\""));
    }

    #[test]
    fn status_blob_round_trips_ok_and_error_shapes() {
        let mut meter = CostMeter::default();
        meter.record_send(7);
        meter.record_recv(9);
        meter.timeouts = (1 << 60) + 3; // bit-pattern transport, not 2^53-limited
        let ok = RankOutcome {
            history: Ok(History::default()),
            tracer: None,
            registry: None,
            meter,
            checkpoint: Some("ckpts/rank1.ckpt".into()),
        };
        let (is_ok, m, err, ckpt) = decode_status(&encode_status(&ok)).unwrap();
        assert!(is_ok);
        assert_eq!(m, meter);
        assert_eq!(err, "");
        assert_eq!(ckpt.as_deref(), Some("ckpts/rank1.ckpt"));

        let failed = RankOutcome {
            history: Err(Error::Comm("rank 2 lost rank 1 (op tag 7)".into())),
            tracer: None,
            registry: None,
            meter: CostMeter::default(),
            checkpoint: None,
        };
        let (is_ok, _, err, ckpt) = decode_status(&encode_status(&failed)).unwrap();
        assert!(!is_ok);
        assert!(err.contains("lost rank 1"), "{err}");
        assert_eq!(ckpt, None);

        // Truncated and trailing-garbage blobs must be rejected, not
        // misread.
        let blob = encode_status(&ok);
        assert!(decode_status(&blob[..blob.len() - 1]).is_none());
        let mut extended = blob.clone();
        extended.push(0.0);
        assert!(decode_status(&extended).is_none());
    }

    #[test]
    fn cg_experiment_converges() {
        let mut c = cfg("cg", 2);
        c.solver.iters = 500;
        let report = run_experiment(&c).unwrap();
        assert!(report.final_sol_err < 1e-6, "{}", report.final_sol_err);
    }
}
