//! End-to-end experiment driver: config → dataset → shards → SPMD solve →
//! report. This is the launcher's core and what the examples call.
//!
//! Dispatch is on the parsed [`Method`] enum (unknown method strings fail
//! at config load), and every solver runs through the engine's single
//! [`Session`](crate::engine::Session) entry point — the driver only
//! chooses the partitioning for the method's layout.

use std::time::Instant;

use crate::comm::cost::CostMeter;
use crate::comm::thread::run_spmd;
use crate::comm::SerialComm;
use crate::config::ExperimentConfig;
use crate::engine::{Layout, Method, Problem, Session};
use crate::error::{Error, Result};
use crate::gram::{ComputeBackend, NativeBackend};
use crate::matrix::gen::{self, DatasetSpec};
use crate::matrix::io::{read_libsvm, Dataset};
use crate::metrics::History;
use crate::runtime::XlaBackend;
use crate::solvers::cg;
use crate::trace::{self, TraceSummary, Tracer};

use super::{partition_dual, partition_primal, partition_rows, DualShard, PrimalShard, RowShard};

/// Everything an experiment produces.
#[derive(Clone, Debug)]
pub struct ExperimentReport {
    pub dataset: String,
    pub d: usize,
    pub n: usize,
    pub method: String,
    pub b: usize,
    pub s: usize,
    pub ranks: usize,
    pub lambda: f64,
    pub backend: String,
    /// Whether the non-blocking overlap pipeline was enabled.
    pub overlap: bool,
    /// Regularizer name (`l2` runs the exact solvers; anything else runs
    /// the CA-Prox loops and reports the prox certificates below).
    pub reg: String,
    /// Driver-level advisories (e.g. "prox run: ridge reference skipped")
    /// — surfaced on stderr and in the report JSON so nothing is dropped
    /// silently.
    pub notes: Vec<String>,
    pub wall_ms: f64,
    /// Rank-0 trajectory.
    pub history: History,
    /// Critical-path communication over all ranks (messages, words).
    pub critical_msgs: u64,
    pub critical_words: u64,
    pub final_obj_err: f64,
    pub final_sol_err: f64,
    /// Per-rank span-trace summary (`[run] trace` / `--trace` only):
    /// compute/wire/idle breakdown, per-kind histograms, and the
    /// overlap-efficiency accounting. The raw Chrome trace-event JSON is
    /// written to the configured path.
    pub trace: Option<TraceSummary>,
}

/// Load the configured dataset (synthetic clone or LIBSVM file) and its λ.
pub fn load_dataset(cfg: &ExperimentConfig) -> Result<(Dataset, f64)> {
    match cfg.dataset.kind.as_str() {
        "synthetic" => {
            let name = cfg.dataset.name.as_ref().ok_or_else(|| {
                Error::Config("synthetic datasets need `dataset.name`".into())
            })?;
            let mut spec: DatasetSpec = gen::spec_by_name(name)?;
            if cfg.dataset.scale > 1 {
                let f = cfg.dataset.scale;
                spec.name = format!("{}-s{}", spec.name, f);
                spec.d = (spec.d / f).max(4);
                spec.n = (spec.n / f).max(16);
            }
            let lam = cfg.effective_lambda(spec.lambda());
            Ok((gen::generate(&spec, cfg.dataset.seed)?, lam))
        }
        "libsvm" => {
            let path = cfg.dataset.path.as_ref().ok_or_else(|| {
                Error::Config("libsvm datasets need `dataset.path`".into())
            })?;
            let ds = read_libsvm(path, None)?;
            let lam = cfg
                .solver
                .lam
                .ok_or_else(|| Error::Config("libsvm datasets need explicit `lam`".into()))?;
            Ok((ds, lam))
        }
        other => Err(Error::Config(format!(
            "unknown dataset kind `{other}` (config validation should have caught this)"
        ))),
    }
}

fn make_backend(cfg: &ExperimentConfig) -> Result<Box<dyn ComputeBackend>> {
    match cfg.run.backend.as_str() {
        "native" => Ok(Box::new(NativeBackend::new())),
        "xla" => Ok(Box::new(XlaBackend::new(&cfg.run.artifact_dir)?)),
        other => Err(Error::Config(format!(
            "unknown backend `{other}` (config validation should have caught this)"
        ))),
    }
}

/// The per-layout shard sets the SPMD closure picks a rank's problem from.
enum ShardSet {
    Primal(Vec<PrimalShard>),
    Dual(Vec<DualShard>),
    Rows(Vec<RowShard>),
}

impl ShardSet {
    fn partition(method: Method, ds: &Dataset, p: usize) -> Result<ShardSet> {
        Ok(match method.layout() {
            Layout::PrimalCols => ShardSet::Primal(partition_primal(ds, p)?),
            Layout::DualCols => ShardSet::Dual(partition_dual(ds, p)?),
            Layout::PrimalRows => ShardSet::Rows(partition_rows(ds, p)?),
        })
    }

    fn problem(&self, rank: usize) -> Problem<'_> {
        match self {
            ShardSet::Primal(v) => {
                let sh = &v[rank];
                Problem::primal(&sh.a_loc, &sh.y_loc, sh.n_global)
            }
            ShardSet::Dual(v) => {
                let sh = &v[rank];
                Problem::dual(&sh.a_loc, &sh.y, sh.d_global, sh.d_offset)
            }
            ShardSet::Rows(v) => {
                let sh = &v[rank];
                Problem::primal_rows(&sh.x_rows, &sh.y_loc, sh.d_global, sh.d_offset)
            }
        }
    }
}

/// Run one configured experiment end to end.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<ExperimentReport> {
    cfg.validate()?;
    let method = cfg.method()?;
    let (ds, lam) = load_dataset(cfg)?;
    let (d, n) = (ds.d(), ds.n());
    let p = cfg.run.ranks;
    let opts = cfg.solver_opts(lam);
    let mut notes: Vec<String> = Vec::new();

    // Ground truth from serial CG (excluded from all meters). The prox
    // runs have no ridge ground truth — they report the duality-gap /
    // subgradient certificates instead, so the CG solve is skipped and
    // the report says so (nothing is dropped silently).
    let reference = if opts.reg.is_exact_l2() {
        let mut comm = SerialComm::new();
        Some(cg::compute_reference(&ds.x, &ds.y, n, lam, &mut comm)?)
    } else {
        let note = format!(
            "reg = {}: ridge reference/CG ground truth does not apply; \
             reporting prox certificates instead of reference errors",
            cfg.solver.reg
        );
        eprintln!("note: {note}");
        notes.push(note);
        None
    };

    let start = Instant::now();
    let shards = ShardSet::partition(method, &ds, p)?;
    let tracing = cfg.run.trace.is_some();
    let results: Vec<Result<(History, Option<Tracer>)>> = run_spmd(p, |rank, comm| {
        if tracing {
            // Per-rank tracer lives in this worker's thread-local slot for
            // the whole solve; reclaimed below even on error so a failed
            // rank cannot leak an active tracer into a reused thread.
            trace::install(Tracer::new(rank, trace::DEFAULT_SPAN_CAPACITY));
        }
        let run_one = || -> Result<History> {
            let mut be = if method.needs_backend() {
                Some(make_backend(cfg)?)
            } else {
                None
            };
            let problem = shards.problem(rank).with_reference(reference.as_ref());
            let mut session = Session::new(&problem)
                .opts(opts.clone())
                .method(method)
                .local_iters(cfg.solver.local_iters)
                .comm(comm);
            if let Some(be) = be.as_mut() {
                session = session.backend(be.as_mut());
            }
            Ok(session.run()?.into_history())
        };
        let history = run_one();
        let tracer = trace::take();
        history.map(|h| (h, tracer))
    });
    let (history, meters, tracers) = collect(results)?;
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;

    let trace_summary = if let Some(path) = cfg.run.trace.as_ref() {
        // Observer gate: every rank's span counts must agree exactly with
        // its CostMeter (one CollectiveStart per posted collective, one
        // CollectiveWait span per completion). A mismatch is an
        // instrumentation bug — surface it as a report advisory rather
        // than failing the solve.
        for (tracer, meter) in tracers.iter().zip(&meters) {
            if let Err(e) = trace::cross_check(tracer, meter) {
                let note = format!("trace/meter cross-check failed: {e}");
                eprintln!("note: {note}");
                notes.push(note);
            }
        }
        std::fs::write(path, trace::chrome_trace_json(&tracers))?;
        Some(TraceSummary::from_tracers(&tracers))
    } else {
        None
    };

    let (critical_msgs, critical_words) = CostMeter::critical_path(&meters);
    Ok(ExperimentReport {
        dataset: ds.name.clone(),
        d,
        n,
        method: cfg.solver.method.clone(),
        b: opts.b,
        s: opts.s,
        ranks: p,
        lambda: lam,
        backend: cfg.run.backend.clone(),
        overlap: opts.overlap,
        reg: {
            use crate::prox::Regularizer;
            opts.reg.name().to_string()
        },
        notes,
        wall_ms,
        final_obj_err: history.final_obj_err(),
        final_sol_err: history.final_sol_err(),
        history,
        critical_msgs,
        critical_words,
        trace: trace_summary,
    })
}

impl ExperimentReport {
    /// JSON for downstream tooling (plotting, EXPERIMENTS.md tables).
    pub fn to_json(&self) -> String {
        use crate::util::json::{array, num, object, string};
        let records = array(self.history.records.iter().map(|r| {
            object(&[
                ("iter", num(r.iter as f64)),
                ("obj_err", num(r.obj_err)),
                ("sol_err", num(r.sol_err)),
            ])
        }));
        let conds = array(self.history.gram_conds.iter().map(|&c| num(c)));
        let prox = array(self.history.prox.iter().map(|r| {
            object(&[
                ("iter", num(r.iter as f64)),
                ("pen_obj", num(r.pen_obj)),
                ("gap", num(r.gap)),
                ("subgrad", num(r.subgrad)),
                ("nnz", num(r.nnz as f64)),
            ])
        }));
        let notes = array(self.notes.iter().map(|s| string(s)));
        object(&[
            ("dataset", string(&self.dataset)),
            ("d", num(self.d as f64)),
            ("n", num(self.n as f64)),
            ("method", string(&self.method)),
            ("b", num(self.b as f64)),
            ("s", num(self.s as f64)),
            ("ranks", num(self.ranks as f64)),
            ("lambda", num(self.lambda)),
            ("backend", string(&self.backend)),
            ("overlap", num(if self.overlap { 1.0 } else { 0.0 })),
            ("reg", string(&self.reg)),
            ("notes", notes),
            ("wall_ms", num(self.wall_ms)),
            ("iters", num(self.history.iters as f64)),
            ("allreduces", num(self.history.meter.allreduces as f64)),
            ("pool_allocs", num(self.history.pool_allocs() as f64)),
            ("critical_msgs", num(self.critical_msgs as f64)),
            ("critical_words", num(self.critical_words as f64)),
            ("final_obj_err", num(self.final_obj_err)),
            ("final_sol_err", num(self.final_sol_err)),
            ("final_pen_obj", num(self.history.final_pen_obj())),
            ("final_gap", num(self.history.final_gap())),
            ("final_subgrad", num(self.history.final_subgrad())),
            (
                "final_nnz",
                num(self
                    .history
                    .final_nnz()
                    .map(|v| v as f64)
                    .unwrap_or(f64::NAN)),
            ),
            (
                "trace",
                self.trace
                    .as_ref()
                    .map(trace::summary_json)
                    .unwrap_or_else(|| "null".into()),
            ),
            ("records", records),
            ("prox_records", prox),
            ("gram_conds", conds),
        ])
    }
}

/// Unwrap per-rank results; rank 0's history is the report's, all meters
/// feed the critical path, all tracers (when tracing) feed the summary.
fn collect(
    results: Vec<Result<(History, Option<Tracer>)>>,
) -> Result<(History, Vec<CostMeter>, Vec<Tracer>)> {
    let mut histories = Vec::with_capacity(results.len());
    let mut tracers = Vec::new();
    for r in results {
        let (h, t) = r?;
        histories.push(h);
        tracers.extend(t);
    }
    let meters: Vec<CostMeter> = histories.iter().map(|h| h.meter).collect();
    Ok((histories.swap_remove(0), meters, tracers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, RunConfig, SolverConfig};

    fn cfg(method: &str, ranks: usize) -> ExperimentConfig {
        ExperimentConfig {
            dataset: DatasetConfig {
                kind: "synthetic".into(),
                name: Some("abalone".into()),
                path: None,
                scale: 8,
                seed: 1,
            },
            solver: SolverConfig {
                method: method.into(),
                b: 2,
                s: 4,
                lam: None,
                iters: 200,
                seed: 3,
                record_every: 50,
                track_gram_cond: false,
                tol: None,
                overlap: false,
                reg: "l2".into(),
                l1_ratio: 0.5,
                local_iters: 100,
            },
            run: RunConfig {
                ranks,
                backend: "native".into(),
                artifact_dir: "artifacts".into(),
                trace: None,
            },
        }
    }

    #[test]
    fn cabcd_experiment_end_to_end() {
        let report = run_experiment(&cfg("cabcd", 2)).unwrap();
        assert_eq!(report.method, "cabcd");
        assert_eq!(report.ranks, 2);
        assert!(report.final_obj_err.is_finite());
        assert!(!report.history.records.is_empty());
        assert!(report.critical_msgs > 0, "P=2 must communicate");
        assert!(report.notes.is_empty(), "l2 run should carry no advisories");
    }

    #[test]
    fn rank_count_does_not_change_numerics() {
        let r1 = run_experiment(&cfg("cabcd", 1)).unwrap();
        let r3 = run_experiment(&cfg("cabcd", 3)).unwrap();
        assert!(
            (r1.final_sol_err - r3.final_sol_err).abs() < 1e-9,
            "P=1 {} vs P=3 {}",
            r1.final_sol_err,
            r3.final_sol_err
        );
    }

    #[test]
    fn overlap_pipeline_reproduces_blocking_results() {
        // Same experiment, blocking vs non-blocking comm: identical final
        // errors (the pipeline is bitwise-equivalent) and identical
        // allreduce counts (still one collective per outer iteration).
        let blocking = run_experiment(&cfg("cabcd", 3)).unwrap();
        let mut c = cfg("cabcd", 3);
        c.solver.overlap = true;
        let overlapped = run_experiment(&c).unwrap();
        assert!(overlapped.overlap);
        assert_eq!(
            blocking.final_sol_err, overlapped.final_sol_err,
            "overlap changed the trajectory"
        );
        assert_eq!(
            blocking.history.meter.allreduces,
            overlapped.history.meter.allreduces
        );
    }

    #[test]
    fn dual_experiment_runs() {
        let report = run_experiment(&cfg("cabdcd", 2)).unwrap();
        assert!(report.final_obj_err.is_finite());
    }

    #[test]
    fn row_layout_experiment_matches_matched_layout() {
        // The new driver-level bcdrow method: same trajectory as the
        // matched-column layout under the same seed (Theorem 4/8), one
        // all-to-all per outer iteration on the wire.
        let col = run_experiment(&cfg("cabcd", 2)).unwrap();
        let row = run_experiment(&cfg("cabcdrow", 2)).unwrap();
        assert!(
            (col.final_sol_err - row.final_sol_err).abs() < 1e-9,
            "col {} vs row {}",
            col.final_sol_err,
            row.final_sol_err
        );
        assert_eq!(row.history.meter.all_to_alls as usize, 200 / 4);
    }

    #[test]
    fn cocoa_experiment_runs_through_session() {
        let mut c = cfg("cocoa", 2);
        c.solver.iters = 30; // rounds
        c.solver.local_iters = 50;
        let report = run_experiment(&c).unwrap();
        assert_eq!(report.method, "cocoa");
        assert!(report.final_obj_err.is_finite());
        // One allreduce per round.
        assert_eq!(report.history.meter.allreduces, 30);
    }

    #[test]
    fn lasso_experiment_reports_prox_certificates() {
        let mut c = cfg("cabcd", 2);
        c.solver.reg = "l1".into();
        c.solver.iters = 400;
        let report = run_experiment(&c).unwrap();
        assert_eq!(report.reg, "l1");
        assert!(!report.history.prox.is_empty(), "no prox records");
        assert!(report.history.final_pen_obj().is_finite());
        assert!(report.history.final_gap().is_finite());
        assert!(report.history.final_nnz().is_some());
        // The prox path skips the ridge reference entirely — and says so.
        assert!(report.history.records.is_empty());
        assert!(
            report.notes.iter().any(|n| n.contains("prox certificates")),
            "missing the reference-skipped advisory: {:?}",
            report.notes
        );
        let json = report.to_json();
        assert!(json.contains("\"prox_records\""));
        assert!(json.contains("\"reg\":\"l1\""));
        assert!(json.contains("\"notes\":["));
    }

    #[test]
    fn reg_l2_reports_match_default_path() {
        // `reg = l2` must be indistinguishable from the pre-prox driver:
        // the exact path runs (reference errors recorded, no prox
        // certificates) with identical trajectories, meters, and
        // critical-path counts.
        let base = run_experiment(&cfg("cabcd", 2)).unwrap();
        let mut c = cfg("cabcd", 2);
        c.solver.reg = "l2".into();
        let explicit = run_experiment(&c).unwrap();
        assert!(explicit.history.prox.is_empty(), "l2 routed into the prox loop");
        assert!(!explicit.history.records.is_empty(), "l2 lost the ridge reference path");
        assert_eq!(base.final_sol_err, explicit.final_sol_err);
        assert_eq!(base.history.meter, explicit.history.meter);
        assert_eq!(base.critical_words, explicit.critical_words);
    }

    #[test]
    fn traced_run_is_observer_neutral_and_writes_chrome_json() {
        let mut c = cfg("cabcd", 2);
        c.solver.overlap = true;
        let plain = run_experiment(&c).unwrap();
        let path = std::env::temp_dir().join("cabcd_driver_trace_test.json");
        c.run.trace = Some(path.clone());
        let traced = run_experiment(&c).unwrap();

        // Observer-neutral: identical trajectory and meters with the
        // tracer installed.
        assert_eq!(plain.final_sol_err, traced.final_sol_err);
        assert_eq!(plain.history.meter, traced.history.meter);

        let sum = traced.trace.as_ref().expect("traced run lost its summary");
        assert_eq!(sum.ranks, 2);
        assert!(sum.spans > 0, "no spans recorded");
        assert_eq!(sum.dropped, 0);
        assert!(
            !traced.notes.iter().any(|n| n.contains("cross-check")),
            "span/meter cross-check failed: {:?}",
            traced.notes
        );
        assert!(traced.to_json().contains("\"overlap_efficiency\""));

        let chrome = std::fs::read_to_string(&path).unwrap();
        assert!(chrome.starts_with("{\"traceEvents\":["));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cg_experiment_converges() {
        let mut c = cfg("cg", 2);
        c.solver.iters = 500;
        let report = run_experiment(&c).unwrap();
        assert!(report.final_sol_err < 1e-6, "{}", report.final_sol_err);
    }
}
