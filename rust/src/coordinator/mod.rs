//! The leader: dataset loading/partitioning, SPMD launch, and experiment
//! reporting — everything between the CLI and the solvers.

pub mod driver;

pub use driver::{
    maybe_run_process_child, run_experiment, run_process_child, AbortInfo, ExperimentReport,
};

use crate::error::Result;
use crate::matrix::io::Dataset;
use crate::matrix::Matrix;
use crate::partition::BlockPartition;

/// One rank's shard for the primal solvers: a column block of X with the
/// matching y slice.
#[derive(Clone, Debug)]
pub struct PrimalShard {
    pub a_loc: Matrix,
    pub y_loc: Vec<f64>,
    pub n_global: usize,
    pub col_offset: usize,
}

/// One rank's shard for the dual solvers: a column block of `A = Xᵀ` (i.e.
/// a feature slice), plus the replicated y.
#[derive(Clone, Debug)]
pub struct DualShard {
    pub a_loc: Matrix,
    pub y: Vec<f64>,
    pub d_global: usize,
    pub d_offset: usize,
}

/// One rank's shard for the row-layout primal solver (Theorem 4/8): a
/// slab of full rows of X plus the y slice for the canonical column range
/// the rank owns.
#[derive(Clone, Debug)]
pub struct RowShard {
    pub x_rows: Matrix,
    pub y_loc: Vec<f64>,
    pub d_global: usize,
    pub d_offset: usize,
}

/// 1D-block-column partition of X for BCD/CA-BCD/CG.
pub fn partition_primal(ds: &Dataset, p: usize) -> Result<Vec<PrimalShard>> {
    let n = ds.n();
    let part = BlockPartition::new(n, p);
    let mut shards = Vec::with_capacity(p);
    for rank in 0..p {
        let (lo, hi) = part.range(rank);
        shards.push(PrimalShard {
            a_loc: ds.x.slice_cols(lo, hi)?,
            y_loc: ds.y[lo..hi].to_vec(),
            n_global: n,
            col_offset: lo,
        });
    }
    Ok(shards)
}

/// 1D-block-row partition of X (= 1D-block-column of Xᵀ) for BDCD/CA-BDCD.
pub fn partition_dual(ds: &Dataset, p: usize) -> Result<Vec<DualShard>> {
    let d = ds.d();
    let at = ds.x.transpose(); // n × d
    let part = BlockPartition::new(d, p);
    let mut shards = Vec::with_capacity(p);
    for rank in 0..p {
        let (lo, hi) = part.range(rank);
        shards.push(DualShard {
            a_loc: at.slice_cols(lo, hi)?,
            y: ds.y.clone(),
            d_global: d,
            d_offset: lo,
        });
    }
    Ok(shards)
}

/// 1D-block-row partition of X for the Theorem-4/8 row-layout solver:
/// rank r gets the canonical row range of X — in X's **native storage**
/// (a CSR dataset stays sparse; the per-iteration redistribution reads
/// row segments through `gather_row_segment`, which handles both kinds)
/// — and the y slice of the canonical column range
/// `BlockPartition::new(n, P)`.
pub fn partition_rows(ds: &Dataset, p: usize) -> Result<Vec<RowShard>> {
    let d = ds.d();
    let n = ds.n();
    let row_part = BlockPartition::new(d, p);
    let col_part = BlockPartition::new(n, p);
    // Row range of X = column range of Xᵀ, transposed back — stays in the
    // dataset's storage format (one O(nnz) transpose shared by all ranks).
    let xt = ds.x.transpose();
    let mut shards = Vec::with_capacity(p);
    for rank in 0..p {
        let (rlo, rhi) = row_part.range(rank);
        let (clo, chi) = col_part.range(rank);
        shards.push(RowShard {
            x_rows: xt.slice_cols(rlo, rhi)?.transpose(),
            y_loc: ds.y[clo..chi].to_vec(),
            d_global: d,
            d_offset: rlo,
        });
    }
    Ok(shards)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::DenseMatrix;

    fn ds() -> Dataset {
        let x = Matrix::Dense(DenseMatrix::from_vec(
            3,
            5,
            vec![
                1., 2., 3., 4., 5., //
                6., 7., 8., 9., 10., //
                11., 12., 13., 14., 15.,
            ],
        ));
        Dataset {
            name: "t".into(),
            x,
            y: vec![1., 2., 3., 4., 5.],
        }
    }

    #[test]
    fn primal_shards_cover_columns() {
        let shards = partition_primal(&ds(), 2).unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].a_loc.cols() + shards[1].a_loc.cols(), 5);
        assert_eq!(shards[0].y_loc.len(), shards[0].a_loc.cols());
        assert_eq!(shards[1].col_offset, shards[0].a_loc.cols());
    }

    #[test]
    fn dual_shards_cover_features() {
        let shards = partition_dual(&ds(), 2).unwrap();
        assert_eq!(shards[0].a_loc.rows(), 5); // n rows in Xᵀ
        assert_eq!(shards[0].a_loc.cols() + shards[1].a_loc.cols(), 3);
        assert_eq!(shards[0].y.len(), 5);
        assert_eq!(shards[1].d_offset, shards[0].a_loc.cols());
    }
}
